"""Fault-injection suite for the guarded matching pipeline.

Uses the deterministic injector (:mod:`repro.testing.faultline`) to prove
the three guard-layer claims:

1. **strict mode catches every injected input fault** — each poisoned
   stream raises a :class:`StreamValidationError` naming the fault kind
   and the planted positions; sanitize drops exactly those edges and is
   bit-identical to a manual drop;
2. **the cascade lands on a correct engine for every injected
   plan/compile fault** — ``on_plan_failure="fallback"`` survives forced
   planner/device/oracle failures (and stale precomputed schedules) with
   a result bit-identical to the scan baseline, recording ``fallback``
   events + counters, and raises :class:`FallbackExhaustedError` naming
   every attempt when *nothing* is left;
3. **the invariant checker flags every injected result corruption** —
   out-of-range/padding/self-loop/ineligible/duplicate ``assigned``
   rewrites and bit-plane flips all raise
   :class:`MatchingInvariantError`.
"""
import numpy as np
import pytest

from repro import obs
from repro.core import (
    EdgeStream,
    StreamValidationError,
    SubstreamConfig,
    check_matching,
    matching_problems,
    merge_host,
    mwm_scan,
    validate_stream,
)
from repro.core.guard import MatchingInvariantError
from repro.graph.waves import validate_schedule, wave_schedule
from repro.kernels.substream_match.ops import (
    FallbackExhaustedError,
    substream_match,
)
from repro.testing import faultline


def _stream(seed=0, n=32, m=120, L=12, pad=0):
    rng = np.random.default_rng(seed)
    stream = EdgeStream.from_numpy(
        rng.integers(0, n, m),
        rng.integers(0, n, m),
        rng.uniform(0.5, 4.0, m),
        n_pad=m + pad,
    )
    return stream, SubstreamConfig(n=n, L=L)


# ---------------------------------------------------------------------------
# 1. Input faults: strict catches, sanitize repairs
# ---------------------------------------------------------------------------

INPUT_FAULTS = {
    "id_past_n": lambda s, cfg: faultline.poison_ids(s, cfg.n, (3, 7), "past_n"),
    "id_sacrificial": lambda s, cfg: faultline.poison_ids(
        s, cfg.n, (0, 11), "sacrificial"
    ),
    "id_negative": lambda s, cfg: faultline.poison_ids(s, cfg.n, (5,), "negative"),
    "id_int_max": lambda s, cfg: faultline.poison_ids(s, cfg.n, (2, 9), "int_max"),
    "weight_nan": lambda s, cfg: faultline.poison_weights(s, (4, 8), "nan"),
    "weight_posinf": lambda s, cfg: faultline.poison_weights(s, (1,), "posinf"),
    "weight_neginf": lambda s, cfg: faultline.poison_weights(s, (6, 13), "neginf"),
    "weight_negative": lambda s, cfg: faultline.poison_weights(s, (10,), "negative"),
}


@pytest.mark.parametrize("fault", sorted(INPUT_FAULTS))
def test_strict_catches_every_input_fault(fault):
    stream, cfg = _stream()
    dirty, info = INPUT_FAULTS[fault](stream, cfg)
    with pytest.raises(StreamValidationError) as exc:
        validate_stream(dirty, cfg.n, policy="strict")
    err = exc.value
    kinds = {p.kind for p in err.problems}
    assert info.kind in kinds, f"{fault}: {kinds} misses {info.kind}"
    prob = next(p for p in err.problems if p.kind == info.kind)
    assert set(info.positions) <= set(prob.indices)
    assert prob.count == len(info.positions)
    # the message is service-log ready: kind + positions, no debugger needed
    assert info.kind in str(err)
    assert str(list(info.positions)[0]) in str(err)


@pytest.mark.parametrize("fault", sorted(INPUT_FAULTS))
def test_sanitize_drops_exactly_the_faulted_edges(fault):
    stream, cfg = _stream()
    dirty, info = INPUT_FAULTS[fault](stream, cfg)
    tel = obs.Telemetry()
    clean, report = validate_stream(dirty, cfg.n, policy="sanitize", telemetry=tel)
    assert report.num_dropped == len(info.positions)
    valid = np.asarray(clean.valid)
    assert not valid[list(info.positions)].any()
    # dropped edges aside, the stream is untouched
    keep = np.ones(stream.num_edges, bool)
    keep[list(info.positions)] = False
    assert (valid[keep] == np.asarray(dirty.valid)[keep]).all()
    # telemetry observed the repair
    assert tel.counters.get("guard.dropped_edges") == len(info.positions)
    assert any(e["name"] == "guard.sanitize" for e in tel.events)
    # and the repaired stream is bit-identical to a manual drop
    manual = EdgeStream(
        src=dirty.src, dst=dirty.dst, weight=dirty.weight,
        valid=np.asarray(dirty.valid) & keep,
    )
    want = mwm_scan(manual, cfg)
    got = mwm_scan(clean, cfg)
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()


def test_off_policy_is_identity():
    stream, cfg = _stream()
    dirty, _ = faultline.poison_ids(stream, cfg.n, (3,), "negative")
    out, report = validate_stream(dirty, cfg.n, policy="off")
    assert out is dirty
    assert report.ok and report.num_dropped == 0


def test_validate_policy_threaded_through_substream_match():
    stream, cfg = _stream()
    dirty, info = faultline.poison_weights(stream, (4, 8), "nan")
    with pytest.raises(StreamValidationError):
        substream_match(dirty, cfg, interpret=True, validate="strict")
    want = mwm_scan(stream, cfg)  # NaN edges dropped == never matched
    got = substream_match(dirty, cfg, interpret=True, validate="sanitize")
    got_a = np.asarray(got.assigned)
    keep = np.ones(stream.num_edges, bool)
    keep[list(info.positions)] = False
    assert (got_a[keep] == np.asarray(want.assigned)[keep]).all()
    assert (got_a[~keep] == -1).all()


# ---------------------------------------------------------------------------
# 2. Plan/compile faults: the cascade degrades, observably, to a correct engine
# ---------------------------------------------------------------------------

PLAN_FAULTS = {
    "mega_plan": (("mega_plan",), "mega"),
    "mega_compile": (("mega_device",), "mega"),
    "mega_then_waves": (("mega_plan", "mega_device", "wave_plan"), "mega"),
    "all_pallas_mega": (
        ("mega_plan", "mega_device", "wave_plan", "waves_device"),
        "mega",
    ),
    "down_to_scan": (
        ("mega_plan", "mega_device", "wave_plan", "waves_device", "waves_xla"),
        "mega",
    ),
    "waves_plan": (("wave_plan",), "waves"),
    "waves_compile": (("waves_device",), "waves"),
    "edges_compile": (("edges_device",), "edges"),
}


@pytest.mark.parametrize("name", sorted(PLAN_FAULTS))
def test_cascade_lands_on_a_correct_engine(name):
    targets, schedule = PLAN_FAULTS[name]
    stream, cfg = _stream(seed=1)
    want = mwm_scan(stream, cfg)
    tel = obs.Telemetry()
    with faultline.failing(*targets):
        got = substream_match(
            stream, cfg, schedule=schedule, interpret=True,
            on_plan_failure="fallback", telemetry=tel,
        )
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()
    # degradation is observable, never silent
    assert tel.counters.get("fallback.count") >= 1
    events = [e for e in tel.events if e["name"] == "fallback"]
    assert events and all("reason" in e and "from_engine" in e for e in events)
    assert any("injected failure" in e["reason"] for e in events)
    # the record of the engine that delivered carries the degradation depth
    if tel.match_calls:
        assert tel.match_calls[-1].counters["fallback.count"] == len(events)
    # the postcondition holds on what the cascade returned
    check_matching(got, stream, cfg)


def test_clean_path_records_zero_fallbacks():
    stream, cfg = _stream(seed=2)
    tel = obs.Telemetry()
    got = substream_match(
        stream, cfg, schedule="mega", interpret=True,
        on_plan_failure="fallback", telemetry=tel,
    )
    assert tel.counters.get("fallback.count") == 0
    assert not [e for e in tel.events if e["name"] == "fallback"]
    assert tel.match_calls[-1].engine == "pallas_mega"
    assert tel.match_calls[-1].counters["fallback.count"] == 0
    assert (
        np.asarray(got.assigned) == np.asarray(mwm_scan(stream, cfg).assigned)
    ).all()


def test_raise_mode_propagates_injected_failures():
    stream, cfg = _stream()
    with faultline.failing("mega_plan"):
        with pytest.raises(faultline.InjectedFailure, match="mega_plan"):
            substream_match(stream, cfg, schedule="mega", interpret=True)


def test_cascade_exhaustion_names_every_attempt():
    stream, cfg = _stream()
    all_engines = (
        "mega_plan", "mega_device", "wave_plan", "waves_device",
        "edges_device", "waves_xla", "scan_oracle",
    )
    with faultline.failing(*all_engines):
        with pytest.raises(FallbackExhaustedError) as exc:
            substream_match(
                stream, cfg, schedule="mega", interpret=True,
                on_plan_failure="fallback",
            )
    labels = [label for label, _ in exc.value.attempts]
    assert labels == [
        "mega", "mega[seg_block=1]", "waves", "waves[block_s=1]",
        "waves_xla", "scan",
    ]
    assert all("injected failure" in str(err) for _, err in exc.value.attempts)


def test_cascade_does_not_absorb_validation_errors():
    stream, cfg = _stream()
    dirty, _ = faultline.poison_ids(stream, cfg.n, (0,), "past_n")
    # a bad stream fails every engine identically; retrying would mask it
    with pytest.raises(StreamValidationError):
        substream_match(
            dirty, cfg, interpret=True, schedule="mega",
            on_plan_failure="fallback", validate="strict",
        )


@pytest.mark.parametrize("corruptor", ["truncate", "permute"])
def test_stale_schedule_is_rejected_then_survived(corruptor):
    stream, cfg = _stream(seed=3)
    src, dst, valid = (
        np.asarray(x) for x in (stream.src, stream.dst, stream.valid)
    )
    sch = wave_schedule(src, dst, valid=valid)
    bad = getattr(faultline, f"{corruptor}_schedule")(sch)
    with pytest.raises(ValueError):
        validate_schedule(bad, src, dst, valid)
    # raise mode: the corruption propagates
    with pytest.raises(ValueError):
        substream_match(stream, cfg, schedule="waves", waves=bad, interpret=True)
    # fallback mode: every schedule consumer fails, scan (which ignores the
    # schedule) still delivers the bit-exact result
    tel = obs.Telemetry()
    got = substream_match(
        stream, cfg, schedule="waves", waves=bad, interpret=True,
        on_plan_failure="fallback", telemetry=tel,
    )
    want = mwm_scan(stream, cfg)
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()
    assert tel.counters.get("fallback.count") >= 1


def test_duplicate_order_entry_is_rejected():
    """An edge scheduled twice in DIFFERENT waves passes the coverage,
    slot-agreement and per-wave disjointness checks — only the
    order-is-a-permutation check stops it (it would double-count the
    edge in the gathered slot stream)."""
    stream, cfg = _stream(seed=5)
    src, dst, valid = (
        np.asarray(x) for x in (stream.src, stream.dst, stream.valid)
    )
    sch = wave_schedule(src, dst, valid=valid)
    bad = faultline.duplicate_order_entry(sch)
    with pytest.raises(ValueError, match="permutation"):
        validate_schedule(bad, src, dst, valid)


def test_fallback_result_repacked_to_requested_storage():
    stream, cfg = _stream(seed=4)
    with faultline.failing(
        "mega_plan", "mega_device", "wave_plan", "waves_device"
    ):
        got = substream_match(
            stream, cfg, schedule="mega", interpret=True,
            on_plan_failure="fallback",
        )
    # the XLA fallbacks produce dense mb; the cascade honours the packed
    # contract of the engine the caller asked for
    assert got.is_packed
    want = mwm_scan(stream, cfg)
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()


# ---------------------------------------------------------------------------
# 3. Result corruptions: check_matching flags every one
# ---------------------------------------------------------------------------


def _first_recorded(res):
    rec = np.nonzero(np.asarray(res.assigned) >= 0)[0]
    assert rec.size, "fixture must record at least one edge"
    return int(rec[0])


def _corrupt_out_of_range(res, stream, cfg):
    return corrupt_at(res, _first_recorded(res), cfg.L + 3), "range"


def _corrupt_negative(res, stream, cfg):
    return corrupt_at(res, _first_recorded(res), -5), "range"


def _corrupt_padding_record(res, stream, cfg):
    pad_pos = int(np.nonzero(~np.asarray(stream.valid))[0][0])
    return corrupt_at(res, pad_pos, 0), "padding"


def _corrupt_ineligible(res, stream, cfg):
    w = np.asarray(stream.weight)
    thr_top = float(np.asarray(cfg.thresholds())[-1])
    pos = np.nonzero(
        (np.asarray(stream.valid))
        & (w < thr_top)
        & (np.asarray(stream.src) != np.asarray(stream.dst))
    )[0]
    assert pos.size, "fixture must contain an edge below the top threshold"
    return corrupt_at(res, int(pos[0]), cfg.L - 1), "threshold"


def _corrupt_bit_cleared_packed(res, stream, cfg):
    p = _first_recorded(res)
    u = int(np.asarray(stream.src)[p])
    sub = int(np.asarray(res.assigned)[p])
    return (
        faultline.flip_matching_bit(faultline.repacked(res), u, sub),
        "matching bit",
    )


def _corrupt_bit_cleared_dense(res, stream, cfg):
    p = _first_recorded(res)
    v = int(np.asarray(stream.dst)[p])
    sub = int(np.asarray(res.assigned)[p])
    return faultline.flip_matching_bit(res, v, sub), "matching bit"


def corrupt_at(res, pos, value):
    return faultline.corrupt_assigned(res, pos, value)


RESULT_FAULTS = {
    "assigned_out_of_range": _corrupt_out_of_range,
    "assigned_negative": _corrupt_negative,
    "assigned_on_padding": _corrupt_padding_record,
    "assigned_ineligible": _corrupt_ineligible,
    "bit_cleared_packed": _corrupt_bit_cleared_packed,
    "bit_cleared_dense": _corrupt_bit_cleared_dense,
}


@pytest.mark.parametrize("fault", sorted(RESULT_FAULTS))
def test_check_matching_flags_every_result_corruption(fault):
    stream, cfg = _stream(seed=5, pad=4)
    res = mwm_scan(stream, cfg)
    check_matching(res, stream, cfg)  # clean baseline passes
    bad, needle = RESULT_FAULTS[fault](res, stream, cfg)
    with pytest.raises(MatchingInvariantError) as exc:
        check_matching(bad, stream, cfg)
    assert needle in str(exc.value)
    assert matching_problems(bad, stream, cfg)


def test_check_matching_flags_duplicate_substream_match():
    # equal-weight star: exactly one hub edge is recorded; duplicating its
    # substream onto a second hub edge breaks per-substream disjointness
    edges = [(0, i, 5.0) for i in range(1, 9)]
    src, dst, w = (np.asarray(x) for x in zip(*edges))
    stream = EdgeStream.from_numpy(src, dst, w)
    cfg = SubstreamConfig(n=9, L=8)
    res = mwm_scan(stream, cfg)
    p = _first_recorded(res)
    other = 1 if p != 1 else 2
    bad = faultline.corrupt_assigned(res, other, int(np.asarray(res.assigned)[p]))
    with pytest.raises(MatchingInvariantError) as exc:
        check_matching(bad, stream, cfg)
    assert "more than once" in str(exc.value)


def test_check_matching_flags_self_loop_record():
    stream, cfg = _stream(seed=6)
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst).copy()
    loop_pos = 0
    dst[loop_pos] = src[loop_pos]
    loop_stream = EdgeStream(
        src=stream.src, dst=dst, weight=stream.weight, valid=stream.valid
    )
    res = mwm_scan(loop_stream, cfg)
    bad = faultline.corrupt_assigned(res, loop_pos, 0)
    with pytest.raises(MatchingInvariantError) as exc:
        check_matching(bad, loop_stream, cfg)
    assert "self-loop" in str(exc.value)


def test_check_matching_covers_the_merge():
    stream, cfg = _stream(seed=7)
    res = mwm_scan(stream, cfg)
    merged = merge_host(stream, res, cfg)
    check_matching(res, stream, cfg, merged=merged)  # clean merge passes
    if merged.size:
        dup = np.concatenate([merged, merged[:1]])
        assert any(
            "twice" in p for p in matching_problems(res, stream, cfg, merged=dup)
        )
    unrecorded = np.nonzero(np.asarray(res.assigned) < 0)[0][:1]
    bad = np.concatenate([merged, unrecorded])
    assert any(
        "never recorded" in p
        for p in matching_problems(res, stream, cfg, merged=bad)
    )
    # a wildly better "exact" optimum violates the (4+eps) bound
    problems = matching_problems(
        res, stream, cfg, merged=merged, exact_weight=1e9
    )
    assert any("bound" in p or "exact" in p for p in problems)
