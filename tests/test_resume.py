"""Resumable chunked streaming: epoch parity, crash matrix, snapshots.

The standing robustness gate for the epoch executor
(:func:`repro.kernels.substream_match.ops.match_epochs`):

* **epoch parity** — every engine chunked into E ∈ {1, 2, 7} epochs is
  bit-identical to the one-shot scan oracle, packed and dense;
* **crash matrix** — kill at every epoch boundary × all six engines ×
  both storage layouts, resume from the latest snapshot, assert
  bit-identity plus a clean ``check_matching`` postcondition;
* **snapshot protocol** — torn commits are invisible (fsync'd
  write-tmp-rename), fingerprint mismatches and corrupt payloads fail
  with structured errors, async saves land.

The graph is small (m = 98 = 7 x 14, so E = 7 slices are equal-length
and the jit variants are shared across kill points) but adversarial
enough: duplicate edges, self-loops, an invalid-masked tail, L % 8 != 0.
"""
import numpy as np
import pytest

from repro import obs
from repro.checkpoint import (
    SnapshotCorruptError,
    SnapshotManager,
    SnapshotMismatchError,
)
from repro.core import MatchState, check_matching
from repro.core.matching import mwm_scan
from repro.core.state import fingerprint_for
from repro.core.types import EdgeStream, SubstreamConfig
from repro.kernels.substream_match.ops import (
    EPOCH_ENGINES,
    epoch_bounds,
    match_epochs,
)
from repro.testing import faultline

N, M, L = 44, 98, 12
EPOCHS = 7


def _build_stream():
    rng = np.random.default_rng(42)
    src = rng.integers(0, N, M).astype(np.int32)
    dst = rng.integers(0, N, M).astype(np.int32)
    w = rng.uniform(1.0, 60.0, M).astype(np.float32)
    src[10] = dst[10] = 7  # self-loop
    src[20], dst[20] = src[21], dst[21] = 3, 9  # duplicate edge
    stream = EdgeStream.from_numpy(src, dst, w)
    # mask a few edges invalid so the mask must survive epoch slicing
    valid = np.asarray(stream.valid).copy()
    valid[[5, 50, 95]] = False
    return EdgeStream(
        src=stream.src, dst=stream.dst, weight=stream.weight,
        valid=np.asarray(valid),
    )


STREAM = _build_stream()
CFG = SubstreamConfig(n=N, L=L)
ORACLE = mwm_scan(STREAM, CFG)
ORACLE_ASSIGNED = np.asarray(ORACLE.assigned)
ORACLE_MB = np.asarray(ORACLE.mb)


def _assert_bit_identical(result):
    assert np.array_equal(np.asarray(result.assigned), ORACLE_ASSIGNED)
    assert np.array_equal(np.asarray(result.mb), ORACLE_MB)


# ------------------------------------------------------------ epoch bounds


def test_epoch_bounds_properties():
    for m in (0, 1, 7, 98, 101):
        for e in (1, 2, 3, 7):
            b = epoch_bounds(m, e)
            assert b[0] == 0 and b[-1] == m and len(b) == e + 1
            assert all(x <= y for x, y in zip(b, b[1:]))
    with pytest.raises(ValueError):
        epoch_bounds(10, 0)


# ------------------------------------------------------------ epoch parity


@pytest.mark.parametrize("packed", [True, False], ids=["packed", "dense"])
@pytest.mark.parametrize("engine", EPOCH_ENGINES)
@pytest.mark.parametrize("epochs", [1, 2, 7])
def test_epoch_parity(engine, epochs, packed):
    """Chunked == one-shot, bit for bit, for every engine and E."""
    out = match_epochs(
        STREAM, CFG, epochs=epochs, engine=engine, packed=packed,
        interpret=True,
    )
    assert out.is_packed == packed
    _assert_bit_identical(out)


def test_epoch_index_telemetry():
    tel = obs.Telemetry()
    match_epochs(STREAM, CFG, epochs=4, engine="scan", telemetry=tel)
    events = [e for e in tel.events if e["name"] == "epoch.index"]
    assert [e["epoch"] for e in events] == [0, 1, 2, 3]
    assert events[0]["start"] == 0 and events[-1]["end"] == M
    assert tel.counters.asdict()["epoch.count"] == 4


def test_unknown_engine_rejected():
    with pytest.raises(ValueError):
        match_epochs(STREAM, CFG, engine="fpga")


# ------------------------------------------------------------- crash matrix


@pytest.mark.parametrize("packed", [True, False], ids=["packed", "dense"])
@pytest.mark.parametrize("engine", EPOCH_ENGINES)
@pytest.mark.parametrize("kill", range(EPOCHS))
def test_kill_and_resume_bit_identical(tmp_path, engine, kill, packed):
    """Kill after epoch ``kill`` snapshots, resume from the latest
    snapshot, and the stitched run equals the one-shot oracle with a
    clean check_matching postcondition."""
    kw = dict(
        epochs=EPOCHS, engine=engine, packed=packed, interpret=True,
    )
    with pytest.raises(faultline.SimulatedCrash):
        match_epochs(
            STREAM, CFG, snapshots=SnapshotManager(tmp_path, async_save=False),
            epoch_hook=faultline.kill_at_epoch(kill), **kw,
        )
    tel = obs.Telemetry()
    out = match_epochs(
        STREAM, CFG, snapshots=SnapshotManager(tmp_path, async_save=False),
        telemetry=tel, **kw,
    )
    _assert_bit_identical(out)
    check_matching(out, STREAM, CFG)
    # the resume replayed only the remaining suffix
    replayed = [e["epoch"] for e in tel.events if e["name"] == "epoch.index"]
    assert replayed == list(range(kill + 1, EPOCHS))


def test_resume_replays_nothing_when_complete(tmp_path):
    snaps = SnapshotManager(tmp_path, async_save=False)
    out1 = match_epochs(
        STREAM, CFG, epochs=3, engine="scan", snapshots=snaps
    )
    tel = obs.Telemetry()
    out2 = match_epochs(
        STREAM, CFG, epochs=3, engine="scan", telemetry=tel,
        snapshots=SnapshotManager(tmp_path, async_save=False),
    )
    _assert_bit_identical(out1)
    _assert_bit_identical(out2)
    assert [e for e in tel.events if e["name"] == "epoch.index"] == []


def test_resume_works_across_engines(tmp_path):
    """Snapshots are engine-agnostic: a run killed under one engine can
    be resumed by another (the state is just (assigned, mb, pos))."""
    with pytest.raises(faultline.SimulatedCrash):
        match_epochs(
            STREAM, CFG, epochs=EPOCHS, engine="mega", interpret=True,
            snapshots=SnapshotManager(tmp_path, async_save=False),
            epoch_hook=faultline.kill_at_epoch(2),
        )
    out = match_epochs(
        STREAM, CFG, epochs=EPOCHS, engine="scan",
        snapshots=SnapshotManager(tmp_path, async_save=False),
    )
    _assert_bit_identical(out)


def test_async_snapshots_land(tmp_path):
    snaps = SnapshotManager(tmp_path, keep=0, async_save=True)
    out = match_epochs(
        STREAM, CFG, epochs=4, engine="scan", snapshots=snaps
    )
    _assert_bit_identical(out)
    assert snaps.all_positions() == epoch_bounds(M, 4)[1:]


def test_snapshot_telemetry_counters(tmp_path):
    tel = obs.Telemetry()
    snaps = SnapshotManager(tmp_path, async_save=False, telemetry=tel)
    match_epochs(STREAM, CFG, epochs=3, engine="scan", snapshots=snaps,
                 telemetry=tel)
    counters = tel.counters.asdict()
    assert counters["snapshot.count"] == 3
    spans = [
        e for e in tel.tracer.events
        if e["name"] == "snapshot.save" and e["ph"] == "X"
    ]
    assert len(spans) == 3


# ------------------------------------------------------- snapshot validation


def test_fingerprint_mismatch_rejected(tmp_path):
    snaps = SnapshotManager(tmp_path, async_save=False)
    with pytest.raises(faultline.SimulatedCrash):
        match_epochs(
            STREAM, CFG, epochs=4, engine="scan", snapshots=snaps,
            epoch_hook=faultline.kill_at_epoch(1),
        )
    other = EdgeStream(
        src=STREAM.src, dst=STREAM.dst, weight=STREAM.weight + 1.0,
        valid=STREAM.valid,
    )
    with pytest.raises(SnapshotMismatchError):
        match_epochs(
            other, CFG, epochs=4, engine="scan",
            snapshots=SnapshotManager(tmp_path, async_save=False),
        )


def test_storage_layout_mismatch_rejected(tmp_path):
    """packed and dense runs fingerprint differently — resuming a packed
    snapshot into a dense run is a mismatch, not a crash."""
    snaps = SnapshotManager(tmp_path, async_save=False)
    with pytest.raises(faultline.SimulatedCrash):
        match_epochs(
            STREAM, CFG, epochs=4, engine="scan", packed=True,
            snapshots=snaps, epoch_hook=faultline.kill_at_epoch(1),
        )
    with pytest.raises(SnapshotMismatchError):
        match_epochs(
            STREAM, CFG, epochs=4, engine="scan", packed=False,
            snapshots=SnapshotManager(tmp_path, async_save=False),
        )


def test_explicit_state_fingerprint_checked():
    other = EdgeStream(
        src=STREAM.src, dst=STREAM.dst, weight=STREAM.weight + 1.0,
        valid=STREAM.valid,
    )
    stale = MatchState.initial(other, CFG, True)
    with pytest.raises(SnapshotMismatchError):
        match_epochs(STREAM, CFG, epochs=2, engine="scan", state=stale)


def test_corrupt_snapshot_rejected(tmp_path):
    """A torn payload (cursors from one epoch, assigned from another)
    fails the structural integrity check at restore."""
    snaps = SnapshotManager(tmp_path, async_save=False)
    with pytest.raises(faultline.SimulatedCrash):
        match_epochs(
            STREAM, CFG, epochs=4, engine="scan", snapshots=snaps,
            epoch_hook=faultline.kill_at_epoch(2),
        )
    # tamper: rewrite the recorded-count cursors inside the npz payload
    import glob
    import os

    latest = sorted(glob.glob(os.path.join(tmp_path, "step_*")))[-1]
    path = os.path.join(latest, "match_state.npz")
    with np.load(path) as data:
        arrays = {k: data[k].copy() for k in data.files}
    arrays["recorded_counts"] = arrays["recorded_counts"] + 1
    np.savez(path, **arrays)
    with pytest.raises(SnapshotCorruptError):
        match_epochs(
            STREAM, CFG, epochs=4, engine="scan",
            snapshots=SnapshotManager(tmp_path, async_save=False),
        )


def test_torn_commit_invisible(tmp_path):
    """kill-mid-snapshot (power loss before the durable rename): the
    partial commit is never visible as a step and the previous snapshot
    remains the latest; a restarted manager recovers cleanly."""
    snaps = SnapshotManager(tmp_path, async_save=False)
    match_epochs(STREAM, CFG, epochs=2, engine="scan", snapshots=snaps)
    committed = snaps.all_positions()
    assert committed == epoch_bounds(M, 2)[1:]

    broken = SnapshotManager(tmp_path, async_save=False)
    faultline.kill_mid_snapshot(broken)
    state = MatchState.initial(STREAM, CFG, True)
    with pytest.raises(faultline.SimulatedCrash):
        broken.save(state)
    # the torn tmp dir exists but is not a committed step
    fresh = SnapshotManager(tmp_path, async_save=False)
    assert fresh.all_positions() == committed
    out = match_epochs(
        STREAM, CFG, epochs=2, engine="scan", snapshots=fresh
    )
    _assert_bit_identical(out)


def test_empty_directory_is_fresh_start(tmp_path):
    out = match_epochs(
        STREAM, CFG, epochs=2, engine="scan",
        snapshots=SnapshotManager(tmp_path, async_save=False),
    )
    _assert_bit_identical(out)


# ------------------------------------------------------------- MatchState


def test_match_state_initial_clean():
    st = MatchState.initial(STREAM, CFG, True)
    assert st.pos == 0 and not st.done and st.mb0 is None
    assert st.problems() == []


def test_match_state_round_trip():
    st = MatchState.initial(STREAM, CFG, True)
    out = match_epochs(STREAM, CFG, epochs=1, engine="scan")
    st = st.advance(out, M)
    assert st.done
    rebuilt = MatchState.from_arrays(st.metadata(), st.to_arrays())
    assert rebuilt.problems() == []
    _assert_bit_identical(rebuilt.result())


def test_match_state_detects_torn_state():
    st = MatchState.initial(STREAM, CFG, True)
    out = match_epochs(STREAM, CFG, epochs=1, engine="scan")
    st = st.advance(out, M)
    torn = MatchState(
        fingerprint=st.fingerprint, pos=st.pos, num_edges=st.num_edges,
        n=st.n, L=st.L, packed=st.packed, assigned=st.assigned,
        mb=st.mb, recorded_counts=st.recorded_counts + 1,
    )
    assert any("recorded_counts" in p for p in torn.problems())


def test_match_state_rejects_partial_result():
    st = MatchState.initial(STREAM, CFG, True)
    with pytest.raises(ValueError):
        st.result()


def test_fingerprint_sensitivity():
    base = fingerprint_for(STREAM, CFG, True)
    assert fingerprint_for(STREAM, CFG, False) != base
    assert fingerprint_for(STREAM, SubstreamConfig(n=N, L=L + 1), True) != base
    other = EdgeStream(
        src=STREAM.src, dst=STREAM.dst, weight=STREAM.weight,
        valid=np.zeros(M, bool),
    )
    assert fingerprint_for(other, CFG, True) != base


# -------------------------------------------------- fallback inside epochs


def test_epochs_with_fallback_cascade(tmp_path):
    """A permanent device fault inside an epoch degrades through the
    PR 8 cascade (mega -> ... -> scan) and the chunked run still
    matches the oracle; snapshots keep committing."""
    snaps = SnapshotManager(tmp_path, keep=0, async_save=False)
    with faultline.failing("mega_device", "waves_device"):
        out = match_epochs(
            STREAM, CFG, epochs=3, engine="mega", interpret=True,
            on_plan_failure="fallback", snapshots=snaps,
        )
    _assert_bit_identical(out)
    assert snaps.all_positions() == epoch_bounds(M, 3)[1:]
