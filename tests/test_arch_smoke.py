"""Per-architecture smoke tests (deliverable f): instantiate the REDUCED
config of each assigned arch, run one forward/train step on CPU, assert
output shapes + no NaNs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_ids, get_arch
from repro.data.pipeline import make_gnn_batch
from repro.models.param import count_params, init_params
from repro.optim import AdamWConfig, adamw_init, adamw_update

LM_IDS = ["internlm2-20b", "minicpm-2b", "gemma-7b", "moonshot-v1-16b-a3b", "grok-1-314b"]
GNN_IDS = ["egnn", "gin-tu", "meshgraphnet", "equiformer-v2"]


def test_registry_complete():
    ids = all_arch_ids()
    assert len(ids) == 10
    for a in LM_IDS + GNN_IDS + ["bert4rec"]:
        assert a in ids


@pytest.mark.parametrize("arch_id", LM_IDS)
def test_lm_smoke_train_step(arch_id):
    from repro.models import transformer as tfm

    arch = get_arch(arch_id)
    cfg = dataclasses.replace(arch.smoke_config, param_dtype=jnp.float32)
    params = init_params(tfm.param_specs(cfg), jax.random.key(0))
    tokens = jax.random.randint(jax.random.key(1), (2, 64), 0, cfg.vocab)
    loss, grads = jax.jit(jax.value_and_grad(lambda p: tfm.loss_fn(p, tokens, cfg)))(
        params
    )
    assert np.isfinite(float(loss))
    opt_cfg = AdamWConfig(lr=1e-3)
    opt = adamw_init(params, opt_cfg)
    new_p, new_opt, gnorm = adamw_update(params, grads, opt, 1e-3, opt_cfg)
    assert np.isfinite(float(gnorm))
    for leaf in jax.tree_util.tree_leaves(new_p):
        assert np.isfinite(np.asarray(leaf)).all()


@pytest.mark.parametrize("arch_id", LM_IDS)
def test_lm_smoke_decode_consistency(arch_id):
    """prefill+decode logits == full forward logits at the next position."""
    from repro.models import transformer as tfm

    arch = get_arch(arch_id)
    # capacity_factor high enough that no token is dropped: MoE capacity
    # competition legitimately differs between batched-forward routing and
    # single-token decode routing (top-1 predictions agree regardless)
    cfg = dataclasses.replace(
        arch.smoke_config, param_dtype=jnp.float32, remat=False,
        capacity_factor=8.0,
    )
    params = init_params(tfm.param_specs(cfg), jax.random.key(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.key(1), (B, S + 1), 0, cfg.vocab)
    cache, _ = tfm.prefill(params, tokens[:, :S], cfg)
    logits_d, _ = tfm.decode_step(params, cache, tokens[:, S], jnp.int32(S), cfg)
    h = tfm.backbone(params, tokens, cfg)
    logits_f = (h[:, S] @ params["lm_head"]).astype(jnp.float32)
    if cfg.logit_softcap:
        logits_f = cfg.logit_softcap * jnp.tanh(logits_f / cfg.logit_softcap)
    np.testing.assert_allclose(
        np.asarray(logits_d), np.asarray(logits_f), rtol=2e-2, atol=2e-2
    )


@pytest.mark.parametrize("arch_id", GNN_IDS)
def test_gnn_smoke_train_step(arch_id):
    import importlib

    arch = get_arch(arch_id)
    mod = importlib.import_module(f"repro.models.{arch.gnn_model}")
    cfg = arch.smoke_config
    params = init_params(mod.param_specs(cfg), jax.random.key(0))
    n_classes = getattr(cfg, "n_classes", 0)
    batch = make_gnn_batch(
        48, 160, cfg.d_in,
        n_classes=n_classes if arch_id == "gin-tu" else 0,
        d_out=getattr(cfg, "d_out", 1),
        coords=True, seed=1,
    )
    loss, grads = jax.jit(jax.value_and_grad(lambda p: mod.loss_fn(p, batch, cfg)))(
        params
    )
    assert np.isfinite(float(loss))
    out = mod.forward(params, batch, cfg)
    out = out[0] if isinstance(out, tuple) else out
    assert out.shape[0] == 48
    assert np.isfinite(np.asarray(out)).all()


def test_bert4rec_smoke():
    from repro.data.pipeline import RecsysPipeline
    from repro.models import bert4rec as b4r

    arch = get_arch("bert4rec")
    cfg = arch.smoke_config
    params = init_params(b4r.param_specs(cfg), jax.random.key(0))
    pipe = RecsysPipeline(cfg.item_vocab, 4, cfg.seq_len, cfg.n_mask,
                          cfg.n_negatives, cfg.n_context)
    batch = pipe.batch_at(0)
    loss = jax.jit(lambda p, b: b4r.loss_fn(p, b, cfg))(params, batch)
    assert np.isfinite(float(loss))
    scores = b4r.serve_scores(params, batch["item_ids"], batch["context_ids"], cfg)
    assert scores.shape == (4, cfg.item_vocab)
    assert np.isfinite(np.asarray(scores)).all()


def test_full_config_param_counts():
    """Published configs hit the expected parameter scales."""
    from repro.models import transformer as tfm

    expect = {
        "internlm2-20b": (17e9, 23e9),
        "minicpm-2b": (2.2e9, 3.3e9),
        "gemma-7b": (8e9, 10e9),  # 8.5B with embeddings
        "grok-1-314b": (290e9, 340e9),
    }
    for arch_id, (lo, hi) in expect.items():
        cfg = get_arch(arch_id).config
        n = cfg.param_count()
        assert lo < n < hi, (arch_id, n)
    moon = get_arch("moonshot-v1-16b-a3b").config
    assert moon.active_param_count() < 0.25 * moon.param_count()
