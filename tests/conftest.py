import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_stream(rng, n, m, L, eps, pad=0, self_loops=False):
    from repro.core import EdgeStream, SubstreamConfig

    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    if not self_loops:
        keep = src != dst
        src, dst = src[keep], dst[keep]
    w = rng.uniform(1.0, cfg.w_max, src.shape[0]).astype(np.float32)
    return EdgeStream.from_numpy(src, dst, w, n_pad=src.shape[0] + pad), cfg
