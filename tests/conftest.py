import pathlib
import sys

import numpy as np
import pytest

# Make `import repro` work even when PYTHONPATH=src was not exported
# (plain `pytest` from the repo root). The repo root itself rides along
# for the suites that exercise `benchmarks.*` (e.g. the bench gate).
_ROOT = pathlib.Path(__file__).resolve().parent.parent
for _p in (str(_ROOT / "src"), str(_ROOT)):
    if _p not in sys.path:
        sys.path.insert(0, _p)

# Property tests want the real hypothesis (declared in requirements.txt);
# in hermetic containers without it, fall back to the deterministic
# minihyp shim so the suite still collects and runs.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from repro.testing import minihyp

    minihyp.install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def make_stream(rng, n, m, L, eps, pad=0, self_loops=False):
    from repro.core import EdgeStream, SubstreamConfig

    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    if not self_loops:
        keep = src != dst
        src, dst = src[keep], dst[keep]
    w = rng.uniform(1.0, cfg.w_max, src.shape[0]).astype(np.float32)
    return EdgeStream.from_numpy(src, dst, w, n_pad=src.shape[0] + pad), cfg
