"""Property suite for the fill-packed earliest-fit wave scheduler.

The packer's contract, on top of the generic wave invariants:

* every wave — and therefore every packed segment row — is
  vertex-disjoint;
* conflicting edges keep their processing order across waves;
* every edge is placed at or past its greedy conflict depth (exactly at
  it when uncapped, which makes the uncapped wave count provably
  minimal);
* the fill-packed [num_segments, SEG] layout carries padding only at
  each wave's tail segment, so the fill never depends on wave-size skew;
* both the packed (uint8 bit-plane) and unpacked (int8) engine layouts
  stay bit-identical to the sequential scan oracle in ``assigned`` and
  ``mb`` — including self-loops, duplicate edges, L % 8 != 0, capped
  (earliest-fit occupancy) schedules, and single-edge streams.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import EdgeStream, SubstreamConfig, mwm_scan, mwm_waves
from repro.graph.waves import (
    SEG,
    block_aligned_layout,
    check_block_aligned,
    check_schedule,
    greedy_depths,
    wave_schedule,
)
from repro.kernels.substream_match.ops import (
    VMEM_PER_CORE,
    mega_plan,
    substream_match,
)

SETTINGS = dict(max_examples=15, deadline=None)


def _stream(draw, max_n=48, max_m=150):
    """Streams biased to the packer edge cases: self-loops and duplicate
    edges (both kept on purpose), padding edges, L % 8 != 0."""
    n = draw(st.integers(4, max_n))
    m = draw(st.integers(1, max_m))
    L = draw(st.sampled_from([1, 4, 9, 16, 33]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    cfg = SubstreamConfig(n=n, L=L, eps=0.1)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    if m > 4 and draw(st.booleans()):  # force exact duplicate edges
        src[m // 2] = src[0]
        dst[m // 2] = dst[0]
    if m > 2 and draw(st.booleans()):  # force a self-loop
        dst[m // 3] = src[m // 3]
    w = rng.uniform(0.5, cfg.w_max * 1.1, m).astype(np.float32)
    pad = draw(st.sampled_from([0, 7]))
    return EdgeStream.from_numpy(src, dst, w, n_pad=m + pad), cfg


@given(st.data())
@settings(**SETTINGS)
def test_packer_invariants_uncapped(data):
    """Uncapped packing = exact conflict depth: wave-count minimal, and
    the packed layout groups each wave's members contiguously with
    padding only at its tail segment."""
    stream, _ = _stream(data.draw)
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    sch = wave_schedule(src, dst, valid=valid)
    check_schedule(sch, src, dst, valid)
    depths = greedy_depths(src, dst, valid=valid)
    assert (sch.wave == depths).all(), "uncapped packing must equal depth"
    # wave count floor: the longest conflict chain; also >= max vertex
    # multiplicity, so no vertex-disjoint decomposition can do better
    assert sch.num_waves == (int(depths.max()) + 1 if valid.any() else 0)
    # fill-packed accounting: one partially-filled segment max per wave
    sizes = sch.wave_sizes()
    assert sch.num_segments == int((-(-sizes // SEG)).sum())
    assert sch.slots.shape == (sch.num_segments, SEG)
    assert sch.num_scheduled == int(valid.sum())
    assert sch.schedule_seconds >= 0.0 and sch.pack_seconds >= 0.0


@given(st.data())
@settings(**SETTINGS)
def test_packer_invariants_capped(data):
    """Earliest-fit with occupancy caps: sizes bounded, every edge at or
    past its depth, conflict order preserved, segments disjoint."""
    stream, _ = _stream(data.draw)
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    cap = data.draw(st.sampled_from([1, 2, 3, 8]))
    sch = wave_schedule(src, dst, valid=valid, max_width=cap)
    check_schedule(sch, src, dst, valid)  # includes the depth floor
    assert (sch.wave_sizes() <= cap).all()
    # capping never reorders conflicts, only delays placements
    depths = greedy_depths(src, dst, valid=valid)
    assert (sch.wave[valid] >= depths[valid]).all()


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_packed_schedule_bit_identity(data):
    """Packed-layout engine results == the sequential scan oracle, for
    uncapped and capped schedules, packed and unpacked bit layouts."""
    stream, cfg = _stream(data.draw, max_n=32, max_m=90)
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    want = mwm_scan(stream, cfg)
    cap = data.draw(st.sampled_from([None, 4]))
    sch = wave_schedule(src, dst, valid=valid, max_width=cap)
    got_xla = mwm_waves(stream, cfg, schedule=sch)
    got_p = substream_match(stream, cfg, schedule="waves", waves=sch, packed=True)
    got_u = substream_match(stream, cfg, schedule="waves", waves=sch, packed=False)
    for got in (got_xla, got_p, got_u):
        assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
        assert (np.asarray(got.mb) == np.asarray(want.mb)).all()


def test_single_edge_stream():
    stream = EdgeStream.from_numpy([0], [1], [3.0])
    cfg = SubstreamConfig(n=4, L=9, eps=0.1)
    sch = wave_schedule(np.asarray(stream.src), np.asarray(stream.dst))
    assert sch.num_waves == 1 and sch.num_segments == 1
    assert sch.fill == 1 / SEG
    want = mwm_scan(stream, cfg)
    got = substream_match(stream, cfg, schedule="waves", waves=sch)
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()


def test_fill_beats_global_padding_on_skew():
    """The motivating case: one hub wave much wider than the rest. The
    old layout padded every wave to the hub width (fill -> 1/max);
    fill-packing bounds the loss at < SEG slots per wave."""
    hub = np.repeat(np.arange(1, 65), 1)  # 64 disjoint edges, one wave
    src = np.concatenate([2 * hub, np.zeros(32, np.int64)])
    dst = np.concatenate([2 * hub + 1, np.arange(200, 232)])
    sch = wave_schedule(src, dst)
    # wave 0 has 65 edges (64 disjoint + first hub edge), then 31 hub
    # waves of one edge each; packed fill stays high regardless
    assert sch.max_wave_size >= 64
    assert sch.fill >= len(src) / (len(src) + SEG * sch.num_waves)
    assert sch.fill > 0.25


def test_packer_determinism():
    rng = np.random.default_rng(3)
    src = rng.integers(0, 30, 200)
    dst = rng.integers(0, 30, 200)
    a = wave_schedule(src, dst)
    b = wave_schedule(src, dst)
    for f in ("wave", "order", "offsets", "slots", "seg_offsets"):
        assert np.array_equal(getattr(a, f), getattr(b, f)), f


@given(st.data())
@settings(**SETTINGS)
def test_block_aligned_offsets_invariants(data):
    """Block-aligned re-layout: offsets monotone and seg_block-aligned,
    every scheduled slot covered exactly once, padding rows only at each
    wave's tail (the last partial tile is pure -1 padding, which the
    mega host prep remaps to the sacrificial row n_pad)."""
    stream, _ = _stream(data.draw)
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    sch = wave_schedule(src, dst, valid=valid)
    sb = data.draw(st.sampled_from([1, 2, 3, 4, 8]))
    layout = block_aligned_layout(sch, sb)
    check_block_aligned(layout, sch)  # coverage, order, tail-only padding
    offs = layout.seg_offsets
    assert offs[0] == 0 and offs[-1] == layout.num_segments
    assert (np.diff(offs) >= 0).all()
    assert (offs % sb == 0).all()
    assert layout.num_segments % sb == 0
    assert layout.num_tiles * sb == layout.num_segments
    # alignment only ever adds padding: fill can't exceed the source's
    assert layout.fill <= sch.fill + 1e-12
    # each wave pays < one full tile of padding rows
    segc = np.diff(sch.seg_offsets)
    assert ((np.diff(offs) - segc) < sb).all()
    # seg_block=1 is the identity re-layout
    if sb == 1:
        assert np.array_equal(layout.slots, sch.slots)


@given(st.data())
@settings(**SETTINGS)
def test_mega_plan_double_buffer_accounting(data):
    """WavePlan VMEM totals under double-buffering: the plan charges
    exactly 2x one tile's working set, and bit block + double-buffered
    tiles + slot-stream blocks all fit in VMEM_PER_CORE."""
    stream, cfg = _stream(data.draw, max_n=40, max_m=120)
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    sch = wave_schedule(src, dst, valid=valid)
    sb = data.draw(st.sampled_from([1, 2, 4]))
    layout = block_aligned_layout(sch, sb)
    packed = data.draw(st.booleans())
    plan = mega_plan(cfg.n, cfg.L, layout, packed=packed)
    assert plan.seg_block == sb
    assert plan.num_tiles == layout.num_tiles
    assert plan.gather_bytes == 2 * plan.tile_bytes, "double-buffer = 2x tile"
    assert plan.block_e == plan.tiles_per_block * sb * plan.seg
    stream_bytes = plan.tiles_per_block * sb * plan.seg * 24 * 2
    assert plan.nbytes + plan.gather_bytes + stream_bytes <= VMEM_PER_CORE
    # the resident bit block itself is within the reserved budget
    assert plan.nbytes == plan.n_pad * plan.width


def test_mega_plan_rejects_oversized_tiles():
    """A seg_block so large the double-buffered tiles can't fit VMEM is
    rejected with the knob named."""
    src = np.arange(0, 4000, 2)
    dst = np.arange(1, 4000, 2)
    sch = wave_schedule(src, dst)
    layout = block_aligned_layout(sch, 32768)
    with pytest.raises(ValueError, match="seg_block"):
        mega_plan(64, 32, layout)


@pytest.mark.parametrize("m", [1, 7, 8, 9, 40000])
def test_conflict_free_stream_packs_full_segments(m):
    """All-independent edges: one wave, ceil(m / SEG) segments, and the
    batched depth passes stay near-linear (no per-edge Python loop)."""
    src = np.arange(0, 2 * m, 2)
    dst = np.arange(1, 2 * m, 2)
    sch = wave_schedule(src, dst)
    assert sch.num_waves == 1
    assert sch.num_segments == -(-m // SEG)
    assert sch.fill == m / (sch.num_segments * SEG)
