"""End-to-end behaviour of the paper's system on a real (small) workload."""
import numpy as np
import pytest

from repro.core import (
    EdgeStream,
    SubstreamConfig,
    exact_mwm_weight,
    gseq,
    matching_weight,
    mwm_pipeline,
)
from repro.data.pipeline import GraphStreamPipeline
from repro.graph.csr import CSRGraph, CustomCSR


@pytest.fixture(scope="module")
def workload():
    pipe = GraphStreamPipeline(scale=8, edge_factor=8, L=16, eps=0.1, seed=0)
    csr = pipe.build()
    src, dst, w = csr.to_stream_arrays()
    stream = EdgeStream.from_numpy(src, dst, w)
    cfg = SubstreamConfig(n=csr.n, L=16, eps=0.1)
    return csr, stream, cfg


def test_pipeline_all_part1_variants_within_bound(workload):
    csr, stream, cfg = workload
    exact = exact_mwm_weight(stream)
    weights = {}
    for variant in ("scan", "blocked", "rounds", "pallas"):
        kw = dict(block_e=256) if variant == "pallas" else {}
        _, wgt = mwm_pipeline(stream, cfg, part1=variant, K=32, **kw)
        weights[variant] = wgt
        assert exact / wgt <= 4 + cfg.eps, (variant, exact, wgt)
    # scan and rounds are the same greedy matching
    assert abs(weights["scan"] - weights["rounds"]) < 1e-3
    # paper Fig. 9: in practice far better than the 4+eps bound
    assert exact / weights["scan"] < 1.5


def test_pipeline_beats_or_matches_gseq_structure(workload):
    """Sanity vs the paper's G-SEQ comparison: both near exact, G-SEQ has
    the tighter bound (2+eps vs 4+eps)."""
    csr, stream, cfg = workload
    exact = exact_mwm_weight(stream)
    gi = gseq(stream, csr.n, cfg.eps)
    gw = matching_weight(stream, gi)
    assert exact / gw <= 2 + cfg.eps


def test_stream_through_custom_csr(workload):
    """The paper's DRAM layout feeds the matcher without altering results."""
    csr, stream, cfg = workload
    cc = CustomCSR.encode(csr)
    back = cc.decode()
    src, dst, w = back.to_stream_arrays()
    stream2 = EdgeStream.from_numpy(src, dst, w)
    _, w1 = mwm_pipeline(stream, cfg)
    _, w2 = mwm_pipeline(stream2, cfg)
    assert abs(w1 - w2) < 1e-3


def test_data_pipeline_determinism():
    from repro.data.pipeline import RecsysPipeline, TokenPipeline

    tp = TokenPipeline(vocab=1000, batch=4, seq_len=16, seed=3)
    assert (tp.batch_at(7) == tp.batch_at(7)).all()
    assert not (tp.batch_at(7) == tp.batch_at(8)).all()
    rp = RecsysPipeline(1000, 4, 16, 4, 32)
    b1, b2 = rp.batch_at(5), rp.batch_at(5)
    assert (np.asarray(b1["item_ids"]) == np.asarray(b2["item_ids"])).all()
