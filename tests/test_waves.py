"""Wave scheduler + wave-vectorized engine properties.

The wave pipeline's contract: decomposing the stream into vertex-disjoint
waves and processing each wave simultaneously is *bit-identical* to the
sequential 1-edge scan (greedy matching is confluent over vertex-disjoint
edges) — across the XLA reference (`mwm_waves`), the packed and unpacked
Pallas wave kernels (`substream_match(schedule="waves")`), the rounds
engine with wave offsets, and the blocked lexicographic pre-order.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    EdgeStream,
    SubstreamConfig,
    lexicographic_order,
    merge_host,
    mwm_blocked,
    mwm_rounds,
    mwm_scan,
    mwm_waves,
    pack_bits,
    permute_stream,
)
from repro.graph.waves import (
    WaveSchedule,
    check_schedule,
    slot_arrays,
    wave_schedule,
)
from repro.kernels.substream_match.ops import (
    VMEM_PER_CORE,
    WavePlan,
    resolve_interpret,
    substream_match,
    wave_plan,
)

SETTINGS = dict(max_examples=15, deadline=None)


def _stream(draw, max_n=48, max_m=150):
    """Streams biased to the wave edge cases: self-loops and duplicate
    edges (both kept on purpose), padding edges, L % 8 != 0."""
    n = draw(st.integers(4, max_n))
    m = draw(st.integers(1, max_m))
    L = draw(st.sampled_from([1, 4, 9, 16, 33]))
    eps = draw(st.sampled_from([0.1, 0.5]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    if m > 4 and draw(st.booleans()):  # force exact duplicate edges
        src[m // 2] = src[0]
        dst[m // 2] = dst[0]
    w = rng.uniform(0.5, cfg.w_max * 1.1, m).astype(np.float32)
    pad = draw(st.sampled_from([0, 7]))
    return EdgeStream.from_numpy(src, dst, w, n_pad=m + pad), cfg


@given(st.data())
@settings(**SETTINGS)
def test_wave_scheduler_invariants(data):
    """Every wave is vertex-disjoint; conflicting edges keep stream order
    across waves; order/offsets/slots agree; padding stays unscheduled."""
    stream, _ = _stream(data.draw)
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    sch = wave_schedule(src, dst, valid=valid)
    check_schedule(sch, src, dst, valid)
    assert sch.num_scheduled == int(valid.sum())
    assert sch.width % 8 == 0
    # the permutation is order-preserving within each wave (stable)
    for k in range(sch.num_waves):
        members = sch.order[sch.offsets[k] : sch.offsets[k + 1]]
        assert (np.diff(members) > 0).all()


@given(st.data())
@settings(**SETTINGS)
def test_wave_scheduler_max_width_split(data):
    stream, _ = _stream(data.draw)
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    cap = data.draw(st.sampled_from([1, 2, 8]))
    sch = wave_schedule(src, dst, valid=valid, max_width=cap)
    check_schedule(sch, src, dst, valid)  # chunks stay vertex-disjoint
    assert (sch.wave_sizes() <= cap).all()
    assert sch.width <= -(-cap // 8) * 8


@given(st.data())
@settings(**SETTINGS)
def test_mwm_waves_equals_scan(data):
    stream, cfg = _stream(data.draw)
    want = mwm_scan(stream, cfg)
    got = mwm_waves(stream, cfg)
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()


@given(st.data())
@settings(max_examples=8, deadline=None)
def test_wave_kernel_equals_scan(data):
    """schedule="waves" is bit-identical to mwm_scan for both layouts,
    and the two layouts ship identical packed words."""
    stream, cfg = _stream(data.draw, max_n=32, max_m=80)
    want = mwm_scan(stream, cfg)
    got_p = substream_match(stream, cfg, schedule="waves", packed=True)
    got_u = substream_match(stream, cfg, schedule="waves", packed=False)
    assert got_p.is_packed and not got_u.is_packed
    assert (np.asarray(got_p.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got_u.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got_p.mb) == np.asarray(want.mb)).all()
    assert (np.asarray(got_u.mb) == np.asarray(want.mb)).all()
    assert (np.asarray(got_p.mb_packed) == np.asarray(pack_bits(want.mb))).all()


@given(st.data())
@settings(**SETTINGS)
def test_rounds_with_waves_equals_scan(data):
    stream, cfg = _stream(data.draw)
    sch = wave_schedule(
        np.asarray(stream.src),
        np.asarray(stream.dst),
        valid=np.asarray(stream.valid),
    )
    want = mwm_scan(stream, cfg)
    got = mwm_rounds(stream, cfg, waves=sch)
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()
    packed = mwm_rounds(stream, cfg, waves=sch, packed=True)
    assert packed.is_packed
    assert (np.asarray(packed.mb) == np.asarray(want.mb)).all()


def test_rounds_waves_rejects_max_rounds(rng):
    from tests.conftest import make_stream

    stream, cfg = make_stream(rng, 16, 40, 8, 0.1)
    sch = wave_schedule(np.asarray(stream.src), np.asarray(stream.dst))
    with pytest.raises(ValueError, match="max_rounds"):
        mwm_rounds(stream, cfg, max_rounds=3, waves=sch)


def test_scheduler_handles_conflict_free_streams_at_scale():
    """All-independent edges (every wave fills to max_width) must stay
    near-linear: the full-wave skip pointers, not a per-edge rescan."""
    m = 40_000
    src = np.arange(0, 2 * m, 2)
    dst = np.arange(1, 2 * m, 2)
    sch = wave_schedule(src, dst, max_width=8)
    assert sch.num_waves == m // 8
    assert (sch.wave_sizes() == 8).all()


def test_wave_kernel_blocked_order(rng):
    """Waves over the lexicographic blocked order: identical to the
    blocked scan reference, end to end through the merge."""
    from tests.conftest import make_stream

    stream, cfg = make_stream(rng, 40, 200, 17, 0.1, self_loops=True)
    want = mwm_blocked(stream, cfg, K=8, backend="scan")
    got = mwm_blocked(stream, cfg, K=8, backend="pallas", schedule="waves")
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()
    assert (merge_host(stream, got, cfg) == merge_host(stream, want, cfg)).all()


def test_wave_schedule_respects_explicit_order(rng):
    """A schedule built over a permuted order serializes conflicts in
    *that* order: running mwm_waves on the permuted stream with the
    stream-order schedule of the permutation matches the permuted scan."""
    from tests.conftest import make_stream

    stream, cfg = make_stream(rng, 24, 120, 16, 0.1, self_loops=True)
    order = np.asarray(lexicographic_order(stream, K=4))
    blocked = permute_stream(stream, order)
    # schedule the *original* stream under the lexicographic order...
    sch = wave_schedule(
        np.asarray(stream.src),
        np.asarray(stream.dst),
        valid=np.asarray(stream.valid),
        order=order,
    )
    check_schedule(sch, np.asarray(stream.src), np.asarray(stream.dst), order=order)
    # ...and the schedule of the permuted stream must induce the same waves
    sch_b = wave_schedule(
        np.asarray(blocked.src),
        np.asarray(blocked.dst),
        valid=np.asarray(blocked.valid),
    )
    assert (sch.wave[order] == sch_b.wave).all()


def test_reused_schedule_across_L(rng):
    """One schedule serves any (L, eps): it depends only on endpoints."""
    from tests.conftest import make_stream

    stream, _ = make_stream(rng, 30, 150, 16, 0.1)
    sch = wave_schedule(
        np.asarray(stream.src),
        np.asarray(stream.dst),
        valid=np.asarray(stream.valid),
    )
    for L, eps in [(1, 0.5), (9, 0.1), (64, 0.05)]:
        cfg = SubstreamConfig(n=30, L=L, eps=eps)
        want = mwm_scan(stream, cfg)
        got = substream_match(stream, cfg, schedule="waves", waves=sch)
        assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
        assert (np.asarray(got.mb) == np.asarray(want.mb)).all()


def test_stale_schedule_rejected(rng):
    """A schedule whose waves are no longer vertex-disjoint for the
    stream (e.g. the stream was permuted after scheduling) must raise,
    not silently corrupt the scatter-add."""
    from tests.conftest import make_stream

    stream, cfg = make_stream(rng, 24, 150, 8, 0.1)
    sch = wave_schedule(
        np.asarray(stream.src), np.asarray(stream.dst),
        valid=np.asarray(stream.valid),
    )
    perm = np.random.default_rng(1).permutation(stream.num_edges)
    shuffled = permute_stream(stream, perm)
    with pytest.raises(ValueError, match="disjoint|cover"):
        substream_match(shuffled, cfg, schedule="waves", waves=sch)
    with pytest.raises(ValueError, match="disjoint|cover"):
        mwm_waves(shuffled, cfg, schedule=sch)
    # coverage mismatch: schedule built ignoring the valid mask
    padded, cfg2 = make_stream(rng, 24, 100, 8, 0.1, pad=9)
    sch_all = wave_schedule(np.asarray(padded.src), np.asarray(padded.dst))
    with pytest.raises(ValueError, match="valid"):
        mwm_waves(padded, cfg2, schedule=sch_all)


def test_schedule_stream_mismatch_raises(rng):
    from tests.conftest import make_stream

    stream, cfg = make_stream(rng, 16, 50, 8, 0.1)
    other, _ = make_stream(rng, 16, 70, 8, 0.1)
    sch = wave_schedule(np.asarray(other.src), np.asarray(other.dst))
    with pytest.raises(ValueError, match="schedule"):
        substream_match(stream, cfg, schedule="waves", waves=sch)
    with pytest.raises(ValueError, match="schedule"):
        mwm_waves(stream, cfg, schedule=sch)
    with pytest.raises(ValueError, match="schedule"):
        substream_match(stream, cfg, schedule="zigzag")


def test_slot_arrays_padding_encoding(rng):
    src = np.array([1, 2, 3, 1])
    dst = np.array([2, 3, 4, 5])
    w = np.array([1.0, 2.0, 3.0, 4.0], np.float32)
    sch = wave_schedule(src, dst)
    u, v, ws, ok = slot_arrays(sch, src, dst, w)
    assert u.shape == (sch.num_segments, sch.width)
    # padding slots can never match: self-loop at vertex 0 with weight 0
    # (the Pallas path additionally remaps them to the sacrificial row)
    assert (u[~ok] == 0).all() and (v[~ok] == 0).all() and (ws[~ok] == 0).all()
    assert ok.sum() == 4


def test_wave_plan_accounting(rng):
    from tests.conftest import make_stream

    stream, cfg = make_stream(rng, 100, 400, 48, 0.1)
    sch = wave_schedule(
        np.asarray(stream.src),
        np.asarray(stream.dst),
        valid=np.asarray(stream.valid),
    )
    for packed in (True, False):
        plan = wave_plan(cfg.n, cfg.L, sch, packed=packed)
        assert isinstance(plan, WavePlan)
        assert plan.seg == sch.width
        assert plan.num_waves == sch.num_waves
        assert plan.num_segments == sch.num_segments
        assert plan.block_e == plan.block_s * plan.seg
        # gather bytes scale with the segment tile, not the largest wave
        assert 0 < plan.gather_bytes <= 16 * sch.width * plan.width + 32 * sch.width
        assert plan.nbytes + plan.gather_bytes <= VMEM_PER_CORE
    # oversized segment tiles must be rejected, pointing at seg
    huge = WaveSchedule(
        wave=np.zeros(1, np.int32),
        order=np.zeros(1, np.int32),
        offsets=np.array([0, 1], np.int32),
        slots=np.zeros((1, 2**22), np.int32),
        seg_offsets=np.array([0, 1], np.int32),
        num_edges=1,
    )
    with pytest.raises(ValueError, match="seg"):
        wave_plan(cfg.n, cfg.L, huge, packed=True)
    # an explicit block_s that overflows the stream buffers names block_s
    with pytest.raises(ValueError, match="block_s"):
        wave_plan(cfg.n, cfg.L, sch, packed=True, block_s=2**24)


@pytest.mark.parametrize("packed", [True, False], ids=["packed", "unpacked"])
def test_wave_path_vmem_budget_enforced(rng, packed):
    """An over-budget *bit block* reports the rounds/partitioning error,
    not the wave-tile max_width one (that's only for oversized waves)."""
    from tests.conftest import make_stream

    stream, _ = make_stream(rng, 16, 40, 4, 0.1)
    big = SubstreamConfig(n=100_000_000, L=512, eps=0.1)
    with pytest.raises(ValueError, match="rounds"):
        substream_match(stream, big, schedule="waves", packed=packed)


def test_resolve_interpret_auto():
    import jax

    on_tpu = jax.default_backend() == "tpu"
    assert resolve_interpret(None) == (not on_tpu)
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False


def test_empty_and_degenerate_streams():
    # single self-loop: one wave, never matches
    stream = EdgeStream.from_numpy([3], [3], [5.0])
    cfg = SubstreamConfig(n=8, L=8, eps=0.1)
    want = mwm_scan(stream, cfg)
    got = substream_match(stream, cfg, schedule="waves")
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    # all-padding stream: zero waves scheduled
    padded = EdgeStream.from_numpy([0], [1], [2.0], n_pad=4)
    padded = EdgeStream(
        src=padded.src, dst=padded.dst, weight=padded.weight,
        valid=np.zeros(4, bool),
    )
    sch = wave_schedule(
        np.asarray(padded.src), np.asarray(padded.dst),
        valid=np.asarray(padded.valid),
    )
    assert sch.num_waves == 0 and sch.num_scheduled == 0
    got = substream_match(padded, cfg, schedule="waves", waves=sch)
    assert (np.asarray(got.assigned) == -1).all()
    assert not np.asarray(got.mb).any()
