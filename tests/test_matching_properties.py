"""Property tests (hypothesis) for the substream-centric matching core."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    EdgeStream,
    SubstreamConfig,
    exact_mwm_weight,
    gseq,
    matching_weight,
    merge_device,
    merge_host,
    mwm_rounds,
    mwm_scan,
    mwm_pipeline,
    pack_bits,
    substream_matchings,
    unpack_bits,
)
from repro.kernels.substream_match.ops import substream_match

SETTINGS = dict(max_examples=20, deadline=None)


def _stream(draw):
    n = draw(st.integers(8, 48))
    m = draw(st.integers(1, 120))
    L = draw(st.sampled_from([1, 4, 16, 33]))
    eps = draw(st.sampled_from([0.05, 0.1, 0.5]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)  # self-loops and duplicates allowed
    w = rng.uniform(0.5, cfg.w_max * 1.1, m).astype(np.float32)
    pad = draw(st.integers(0, 8))
    return EdgeStream.from_numpy(src, dst, w, n_pad=m + pad), cfg


stream_cfg = st.builds(lambda d: d, st.data()).map(lambda d: None)  # unused


@given(st.data())
@settings(**SETTINGS)
def test_substream_matchings_are_matchings_and_maximal(data):
    stream, cfg = _stream(data.draw)
    added = np.asarray(substream_matchings(stream, cfg))  # [m, L]
    res = np.asarray(mwm_scan(stream, cfg).mb)
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    w = np.asarray(stream.weight)
    valid = np.asarray(stream.valid)
    thr = (1 + cfg.eps) ** np.arange(cfg.L)
    for i in range(cfg.L):
        sel = added[:, i]
        verts = np.concatenate([src[sel], dst[sel]])
        # matching: no vertex repeated
        assert len(verts) == len(set(verts.tolist()))
        # mb consistency
        assert set(np.nonzero(res[:, i])[0].tolist()) == set(verts.tolist())
        # maximality: every eligible valid edge has a matched endpoint
        elig = valid & (w >= thr[i]) & (src != dst)
        for e in np.nonzero(elig & ~sel)[0]:
            assert res[src[e], i] or res[dst[e], i]


def _small_stream(draw):
    """Like _stream but kernel-sized (the Pallas interpreter retraces per
    shape) and biased to exercise the packed layout's edge cases: L not
    divisible by 8, self-loops (src == dst draws) and padding edges."""
    n = draw(st.integers(8, 32))
    m = draw(st.integers(1, 60))
    L = draw(st.sampled_from([1, 4, 9, 16, 33]))
    eps = draw(st.sampled_from([0.1, 0.5]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)  # self-loops and duplicates allowed
    w = rng.uniform(0.5, cfg.w_max * 1.1, m).astype(np.float32)
    pad = draw(st.sampled_from([0, 5]))
    return EdgeStream.from_numpy(src, dst, w, n_pad=m + pad), cfg


@given(st.data())
@settings(max_examples=8, deadline=None)
def test_packed_layout_parity(data):
    """Packed and unpacked kernels are bit-identical in `assigned` and the
    unpacked `mb` view, and agree with the scan oracle."""
    stream, cfg = _small_stream(data.draw)
    want = mwm_scan(stream, cfg)
    got_p = substream_match(stream, cfg, block_e=32, packed=True)
    got_u = substream_match(stream, cfg, block_e=32, packed=False)
    assert (np.asarray(got_p.assigned) == np.asarray(got_u.assigned)).all()
    assert (np.asarray(got_p.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got_p.mb) == np.asarray(got_u.mb)).all()
    assert (np.asarray(got_p.mb) == np.asarray(want.mb)).all()
    assert (np.asarray(got_p.mb_packed) == np.asarray(pack_bits(want.mb))).all()


@given(st.data())
@settings(**SETTINGS)
def test_bitpack_roundtrip_property(data):
    L = data.draw(st.integers(1, 70))
    n = data.draw(st.integers(1, 40))
    seed = data.draw(st.integers(0, 2**31 - 1))
    mb = np.random.default_rng(seed).integers(0, 2, (n, L)).astype(bool)
    assert (np.asarray(unpack_bits(pack_bits(mb), L)) == mb).all()


@given(st.data())
@settings(**SETTINGS)
def test_rounds_equals_scan(data):
    stream, cfg = _stream(data.draw)
    a = mwm_scan(stream, cfg)
    b = mwm_rounds(stream, cfg)
    assert (np.asarray(a.assigned) == np.asarray(b.assigned)).all()
    assert (np.asarray(a.mb) == np.asarray(b.mb)).all()
    # packed shipping format unpacks to the same bits
    p = mwm_rounds(stream, cfg, packed=True)
    assert p.is_packed
    assert (np.asarray(p.mb) == np.asarray(a.mb)).all()


@given(st.data())
@settings(**SETTINGS)
def test_merge_host_equals_device_and_T_is_matching(data):
    stream, cfg = _stream(data.draw)
    res = mwm_scan(stream, cfg)
    idx = merge_host(stream, res, cfg)
    mask = np.asarray(merge_device(stream, res, cfg))
    assert (np.nonzero(mask)[0] == idx).all()
    src = np.asarray(stream.src)[idx]
    dst = np.asarray(stream.dst)[idx]
    verts = np.concatenate([src, dst])
    assert len(verts) == len(set(verts.tolist()))


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_approximation_bound(data):
    """w(M*) / w(T) <= 4 + eps — the paper's Crouch–Stubbs guarantee.

    Edges below substream 0's threshold can never be picked, so restrict
    weights to [1, w_max] (the paper's §5.1.4 weight regime).
    """
    n = data.draw(st.integers(6, 28))
    m = data.draw(st.integers(1, 60))
    L = data.draw(st.sampled_from([16, 32]))
    eps = 0.1
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    if len(src) == 0:
        return
    w = rng.uniform(1.0, cfg.w_max, len(src)).astype(np.float32)
    stream = EdgeStream.from_numpy(src, dst, w)
    idx, weight = mwm_pipeline(stream, cfg, part1="scan")
    exact = exact_mwm_weight(stream)
    assert weight > 0 or exact == 0
    if weight > 0:
        assert exact / weight <= 4 + eps + 1e-3


def test_gseq_bound(rng):
    from tests.conftest import make_stream

    stream, cfg = make_stream(rng, 40, 150, 16, 0.1)
    gi = gseq(stream, 40, 0.1)
    gw = matching_weight(stream, gi)
    exact = exact_mwm_weight(stream)
    assert exact / gw <= 2 + 0.1 + 1e-3
    # G-SEQ's result is a matching
    src = np.asarray(stream.src)[gi]
    dst = np.asarray(stream.dst)[gi]
    verts = np.concatenate([src, dst])
    assert len(verts) == len(set(verts.tolist()))


def test_blocked_matches_quality(rng):
    """Blocked (Listing 2) output differs from CS-SEQ but keeps the bound."""
    from repro.core import mwm_blocked
    from tests.conftest import make_stream

    stream, cfg = make_stream(rng, 32, 120, 16, 0.1)
    for K in (1, 4, 32):
        res = mwm_blocked(stream, cfg, K=K)
        idx = merge_host(stream, res, cfg)
        weight = matching_weight(stream, idx)
        exact = exact_mwm_weight(stream)
        assert exact / max(weight, 1e-9) <= 4 + cfg.eps + 1e-3
