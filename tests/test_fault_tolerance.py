"""Fault-tolerance unit coverage: remesh ladders, straggler EWMA, guard.

The file the ``distributed/`` docstrings point at: ``plan_remesh``
degradation across shrinking device pools, host-device mesh rebuilds,
``StragglerMonitor`` with injected delays, and the
:class:`repro.core.executor.ExecutionGuard` retry/backoff/deadline
machinery driven deterministically by faultline's ``FakeClock``.
"""
import jax
import numpy as np
import pytest

from repro import obs
from repro.core import ExecutionGuard, RetriesExhaustedError, is_transient
from repro.core.executor import DeadlineExceededError
from repro.core.matching import mwm_scan
from repro.core.types import EdgeStream, SubstreamConfig
from repro.distributed import StragglerMonitor, plan_remesh
from repro.distributed.elastic import build_mesh
from repro.kernels.substream_match.ops import match_epochs
from repro.testing import faultline


# ------------------------------------------------------------ plan_remesh


@pytest.mark.parametrize("n", [1, 2, 3, 4, 5, 8, 9, 16, 17, 31, 32, 48, 64, 100])
def test_plan_remesh_invariants(n):
    plan = plan_remesh(n)
    assert plan.data >= 1 and plan.model >= 1
    assert plan.n_devices == plan.data * plan.model
    assert plan.n_devices <= n
    assert plan.dropped_devices == n - plan.n_devices
    assert plan.model <= 16  # never exceeds prefer_model


def test_plan_remesh_degradation_ladder():
    """Shrinking pools keep producing legal meshes; the model axis never
    grows as devices drop, and full pools waste nothing."""
    prev_model = None
    for n in (64, 32, 16, 8, 4, 2, 1):
        plan = plan_remesh(n)
        assert plan.dropped_devices == 0  # powers of two pack exactly
        if prev_model is not None:
            assert plan.model <= prev_model
        prev_model = plan.model


def test_plan_remesh_prefers_model_axis():
    plan = plan_remesh(64, prefer_model=16)
    assert plan.model == 16 and plan.data == 4


def test_plan_remesh_min_model_floor():
    plan = plan_remesh(3, prefer_model=16, min_model=1)
    assert plan.model >= 1
    assert plan.n_devices <= 3


def test_build_mesh_host_devices():
    n = len(jax.devices())
    plan = plan_remesh(n)
    mesh = build_mesh(plan)
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.shape == (plan.data, plan.model)


# ------------------------------------------------------- StragglerMonitor


def test_straggler_warmup_and_seed():
    mon = StragglerMonitor(alpha=0.5, threshold=2.0, warmup_steps=3)
    assert mon.observe(1.0) is None  # seeds the EWMA
    assert mon.ewma == 1.0
    # inside warmup even a huge outlier is not flagged
    assert mon.observe(10.0) is None
    assert len(mon.events) == 0


def test_straggler_flags_injected_delay():
    mon = StragglerMonitor(alpha=0.1, threshold=2.0, warmup_steps=2)
    for _ in range(4):
        mon.observe(1.0)
    ewma_before = mon.ewma
    event = mon.observe(5.0)  # injected straggler
    assert event is not None
    assert event.ratio == pytest.approx(5.0 / ewma_before)
    # the outlier must not pollute the EWMA
    assert mon.ewma == ewma_before
    assert list(mon.events) == [event]


def test_straggler_normal_steps_update_ewma():
    mon = StragglerMonitor(alpha=0.5, threshold=10.0, warmup_steps=1)
    mon.observe(1.0)
    mon.observe(2.0)
    assert mon.ewma == pytest.approx(1.5)


def test_straggler_history_bounded():
    mon = StragglerMonitor(alpha=0.1, threshold=1.5, warmup_steps=0, history=3)
    mon.observe(1.0)
    for _ in range(10):
        mon.observe(100.0)
    assert len(mon.events) == 3


# --------------------------------------------------------- classification


def test_is_transient_classification():
    assert is_transient(faultline.TransientFlake("x"))
    assert is_transient(TimeoutError("x"))
    assert is_transient(ConnectionError("x"))
    assert is_transient(DeadlineExceededError(2.0, 1.0))
    assert not is_transient(ValueError("x"))
    assert not is_transient(RuntimeError("x"))

    class PinnedPermanent(TimeoutError):
        transient = False

    assert not is_transient(PinnedPermanent("x"))


# ---------------------------------------------------------- ExecutionGuard


def _guard(clk, **kw):
    kw.setdefault("retries", 3)
    kw.setdefault("backoff", 0.05)
    kw.setdefault("backoff_factor", 2.0)
    return ExecutionGuard(clock=clk, sleep=clk.sleep, **kw)


def test_guard_clean_path_no_retries():
    clk = faultline.FakeClock()
    tel = obs.Telemetry()
    g = _guard(clk, telemetry=tel)
    assert g.run(lambda: "ok") == "ok"
    assert clk.sleeps == []
    assert g.retry_log == []
    assert "guard.retry" not in tel.counters.asdict()


def test_guard_backoff_schedule_exact():
    """Retry delays follow base * factor**attempt exactly."""
    clk = faultline.FakeClock()
    tel = obs.Telemetry()
    g = _guard(clk, telemetry=tel)
    fn = faultline.flake(lambda: 42, times=3)
    assert g.run(fn) == 42
    assert clk.sleeps == [0.05, 0.10, 0.20]
    assert [d for (_, _, d) in g.retry_log] == [0.05, 0.10, 0.20]
    assert tel.counters.asdict()["guard.retry"] == 3
    retry_events = [e for e in tel.events if e["name"] == "guard.retry"]
    assert [e["attempt"] for e in retry_events] == [0, 1, 2]
    assert [e["delay_seconds"] for e in retry_events] == [0.05, 0.10, 0.20]


def test_guard_retries_exhausted():
    clk = faultline.FakeClock()
    g = _guard(clk, retries=2)
    fn = faultline.flake(lambda: 42, times=99)
    with pytest.raises(RetriesExhaustedError) as exc:
        g.run(fn)
    assert len(exc.value.attempts) == 3  # first try + 2 retries
    assert clk.sleeps == [0.05, 0.10]


def test_guard_permanent_fault_no_retry():
    clk = faultline.FakeClock()
    g = _guard(clk)
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        raise ValueError("permanent")

    with pytest.raises(ValueError):
        g.run(fn)
    assert calls["n"] == 1
    assert clk.sleeps == []


def test_guard_deadline_exceeded_is_retried():
    clk = faultline.FakeClock()
    g = _guard(clk, deadline=1.0, retries=1)
    slow_then_fast = {"n": 0}

    def fn():
        slow_then_fast["n"] += 1
        clk.advance = 5.0 if slow_then_fast["n"] == 1 else 0.01
        return "done"

    assert g.run(fn) == "done"
    assert slow_then_fast["n"] == 2
    assert isinstance(g.retry_log[0][1], DeadlineExceededError)


def test_guard_deadline_exhausts_to_error():
    clk = faultline.FakeClock()
    g = _guard(clk, deadline=1.0, retries=1)
    with pytest.raises(RetriesExhaustedError) as exc:
        g.run(faultline.slow(lambda: "x", clk, 5.0))
    assert all(isinstance(e, DeadlineExceededError) for e in exc.value.attempts)


def test_guard_never_absorbs_simulated_crash():
    clk = faultline.FakeClock()
    g = _guard(clk)

    def fn():
        raise faultline.SimulatedCrash("kill -9")

    with pytest.raises(faultline.SimulatedCrash):
        g.run(fn)
    assert clk.sleeps == []


def test_guard_feeds_straggler_monitor():
    clk = faultline.FakeClock()
    tel = obs.Telemetry()
    mon = StragglerMonitor(alpha=0.1, threshold=2.0, warmup_steps=2)
    g = _guard(clk, monitor=mon, telemetry=tel)
    for _ in range(4):
        g.run(faultline.slow(lambda: None, clk, 1.0))
    g.run(faultline.slow(lambda: None, clk, 8.0))  # injected straggler
    events = [e for e in tel.events if e["name"] == "guard.straggler"]
    assert len(events) == 1
    assert events[0]["ratio"] > 2.0
    assert tel.counters.asdict()["guard.straggler"] == 1


def test_guard_rejects_negative_retries():
    with pytest.raises(ValueError):
        ExecutionGuard(retries=-1)


# --------------------------------------- guard + epoch executor integration


def _small_stream(seed=7, n=32, m=96, L=8):
    rng = np.random.default_rng(seed)
    stream = EdgeStream.from_numpy(
        rng.integers(0, n, m).astype(np.int32),
        rng.integers(0, n, m).astype(np.int32),
        rng.uniform(1.0, 40.0, m).astype(np.float32),
    )
    return stream, SubstreamConfig(n=n, L=L)


def test_flaky_engine_retried_bit_exact():
    """A transient flake in the scan engine is retried by the guard and
    the chunked run still matches the one-shot oracle bit-for-bit."""
    stream, cfg = _small_stream()
    ref = mwm_scan(stream, cfg)
    clk = faultline.FakeClock()
    tel = obs.Telemetry()
    g = _guard(clk, telemetry=tel)
    with faultline.flaky("scan_oracle", times=1):
        out = match_epochs(
            stream, cfg, epochs=3, engine="scan", guard=g, telemetry=tel
        )
    assert np.array_equal(np.asarray(out.assigned), np.asarray(ref.assigned))
    assert np.array_equal(np.asarray(out.mb), np.asarray(ref.mb))
    assert tel.counters.asdict()["guard.retry"] == 1
    assert clk.sleeps == [0.05]


def test_transient_flake_exhaustion_propagates():
    stream, cfg = _small_stream()
    clk = faultline.FakeClock()
    g = _guard(clk, retries=1)
    with faultline.flaky("scan_oracle", times=99):
        with pytest.raises(RetriesExhaustedError):
            match_epochs(stream, cfg, epochs=2, engine="scan", guard=g)
