"""Unit tests for the bench acceptance gate (`bench_throughput.check_report`).

The gate is a pure function (synthetic report dict in, verdict +
messages out) precisely so raising it — e.g. to ISSUE 6's
``mega >= waves_xla`` — cannot be silently broken by a bench refactor:
these tests pin the pass/fail semantics, the per-gate messages, and the
loud failure on structurally broken reports. Since the telemetry gates
landed, every engine row must also carry a complete ``stage_seconds``
split and a counter set matching the embedded ``expected_counters``
plan accounting bit-exactly — missing or inconsistent telemetry fails
the gate too. The guard gate additionally requires a clean per-graph
``validation`` record and ``fallback.count == 0`` on every Pallas row:
a bench number must come from the engine it is labeled with. Gate 7
(the recovery gate) requires every graph to embed a ``recovery`` block
from the resumable path with snapshot producer stall within budget, a
bit-exact killed-and-resumed result, and zero clean-path retries.
"""
import json
import pathlib
import sys

import pytest

from benchmarks.bench_throughput import (
    TARGET_FILL,
    TARGET_MEGA_VS_XLA,
    TARGET_SNAPSHOT_OVERHEAD_PCT,
    TARGET_SPEEDUP,
    check_report,
)

#: Gate messages: 3 perf gates + telemetry structure + plan counters
#: + the clean-path guard (validation clean, no fallback degradation)
#: + the recovery gate (snapshot stall, bit-exact resume, zero retries).
N_GATES = 7

_WAVES_EXPECT = {
    "plan.gather_bytes": 960,
    "plan.bit_block_bytes": 8192,
    "traffic.hbm_bytes": 100_000,
}
_MEGA_EXPECT = {
    "plan.gather_bytes": 4352,
    "plan.bit_block_bytes": 8192,
    "traffic.hbm_bytes": 120_000,
}


def _engine_row(counters=None):
    return {
        "seconds_per_call": 0.01,
        "edges_per_sec": 1e6,
        "reps": 3,
        "backend": "cpu",
        "interpret": True,
        "stage_seconds": {
            "schedule": 0.001,
            "pack": 0.0005,
            "layout": 0.002,
            "compile": 0.1,
            "execute": 0.01,
        },
        "telemetry_wall_seconds": 0.2,
        "counters": dict(counters or {"stream.num_edges": 8192}),
    }


def _graph(scale=10, speedup=9.0, fill=0.7, mega=1.3):
    engines = {name: _engine_row() for name in ("scan", "waves_xla", "rounds")}
    engines["pallas_edges"] = _engine_row(
        {"stream.num_edges": 8192, "fallback.count": 0}
    )
    engines["pallas_waves"] = _engine_row(
        {"stream.num_edges": 8192, "fallback.count": 0, **_WAVES_EXPECT}
    )
    engines["pallas_mega"] = _engine_row(
        {"stream.num_edges": 8192, "fallback.count": 0, **_MEGA_EXPECT}
    )
    return {
        "scale": scale,
        "speedup_pallas_waves_vs_edges": speedup,
        "wave_fill": fill,
        "speedup_mega_vs_xla": mega,
        "validation": {
            "policy": "strict",
            "guard.num_edges": 8192,
            "guard.num_valid_in": 8192,
            "guard.dropped_edges": 0,
            "guard.num_problems": 0,
        },
        "expected_counters": {
            "pallas_waves": dict(_WAVES_EXPECT),
            "pallas_mega": dict(_MEGA_EXPECT),
        },
        "recovery": {
            "epochs": 4,
            "engine": "mega",
            "chunked_seconds": 0.013,
            "chunked_snapshot_seconds": 0.019,
            "snapshot_stall_seconds": 0.0004,
            "snapshot_overhead_pct": 3.1,
            "flush_seconds": 0.003,
            "kill_after_epoch": 1,
            "recover_seconds": 0.018,
            "resumed_bit_exact": True,
            "clean_retries": 0,
        },
        "engines": engines,
    }


def _report(graphs):
    return {"benchmark": "bench_throughput", "graphs": graphs}


def test_all_gates_pass():
    ok, msgs = check_report(_report([_graph(10), _graph(12), _graph(14)]))
    assert ok
    assert len(msgs) == N_GATES
    assert all(m.startswith("PASS") for m in msgs)


def test_mega_gate_fails_below_xla():
    """The raised gate: mega slower than the XLA oracle on ANY scale fails."""
    graphs = [_graph(10), _graph(12, mega=0.97), _graph(14)]
    ok, msgs = check_report(_report(graphs))
    assert not ok
    fail = [m for m in msgs if m.startswith("FAIL")]
    assert len(fail) == 1
    assert "mega" in fail[0] and "scale 12" in fail[0]


def test_mega_gate_boundary_is_inclusive():
    ok, _ = check_report(_report([_graph(mega=TARGET_MEGA_VS_XLA)]))
    assert ok
    ok, _ = check_report(_report([_graph(mega=TARGET_MEGA_VS_XLA - 1e-6)]))
    assert not ok


def test_speedup_and_fill_gates_still_enforced():
    ok, msgs = check_report(_report([_graph(speedup=TARGET_SPEEDUP - 0.1)]))
    assert not ok and any("pallas_edges" in m for m in msgs if "FAIL" in m)
    ok, msgs = check_report(_report([_graph(fill=TARGET_FILL / 2)]))
    assert not ok and any("fill" in m for m in msgs if "FAIL" in m)


def test_worst_scale_is_named():
    """The message names the scale where the minimum occurred."""
    graphs = [_graph(10, fill=0.9), _graph(14, fill=0.51)]
    ok, msgs = check_report(_report(graphs))
    assert ok
    fill_msg = next(m for m in msgs if "fill" in m)
    assert "scale 14" in fill_msg


def test_broken_report_fails_loudly():
    """No graphs / missing keys can never pass vacuously."""
    ok, msgs = check_report({})
    assert not ok and "no graphs" in msgs[0]
    ok, msgs = check_report(_report([]))
    assert not ok
    g = _graph()
    del g["speedup_mega_vs_xla"]
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any("missing" in m for m in msgs)


def test_missing_stage_seconds_fails():
    """An engine row without its telemetry stage split fails loudly."""
    g = _graph()
    del g["engines"]["pallas_mega"]["stage_seconds"]
    ok, msgs = check_report(_report([g]))
    assert not ok
    msg = next(m for m in msgs if "stage_seconds" in m and m.startswith("FAIL"))
    assert "pallas_mega" in msg


def test_missing_stage_key_fails():
    """All five canonical stage keys are required on every row."""
    g = _graph()
    del g["engines"]["rounds"]["stage_seconds"]["compile"]
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any("compile" in m and "rounds" in m for m in msgs)


def test_inconsistent_stage_sum_fails():
    """Stage sums exceeding the instrumented wall time fail the gate
    (stages are disjoint subintervals, so the sum can never exceed it)."""
    g = _graph()
    g["engines"]["scan"]["stage_seconds"]["execute"] = 10.0
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any("exceeds wall" in m for m in msgs)


def test_empty_counters_fails():
    g = _graph()
    g["engines"]["waves_xla"]["counters"] = {}
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any("no counters" in m and "waves_xla" in m for m in msgs)


def test_plan_counter_mismatch_fails_bit_exactly():
    """A single off-by-one in the emitted gather bytes is a gate failure —
    the counters must equal the recomputed plan accounting exactly."""
    g = _graph()
    g["engines"]["pallas_waves"]["counters"]["plan.gather_bytes"] += 1
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any(
        "plan.gather_bytes" in m and "pallas_waves" in m for m in msgs
    )
    g2 = _graph()
    del g2["engines"]["pallas_mega"]["counters"]["traffic.hbm_bytes"]
    ok, msgs = check_report(_report([g2]))
    assert not ok
    assert any("traffic.hbm_bytes" in m and "missing" in m for m in msgs)


def test_missing_expected_counters_fails():
    """A report that stops embedding the plan accounting cannot pass."""
    g = _graph()
    del g["expected_counters"]
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any("expected_counters" in m for m in msgs)


def test_missing_validation_block_fails():
    """A report that stops recording the guard validation cannot pass."""
    g = _graph()
    del g["validation"]
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any("no validation block" in m for m in msgs)


def test_dirty_clean_path_fails():
    """Any dropped edge / detected problem on the bench path is a FAIL —
    the clean workload generator must never need sanitizing."""
    g = _graph()
    g["validation"]["guard.dropped_edges"] = 3
    g["validation"]["guard.num_problems"] = 1
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any("guard.dropped_edges = 3" in m for m in msgs)
    assert any("guard.num_problems = 1" in m for m in msgs)


def test_nonzero_fallback_count_fails():
    """A Pallas row that silently degraded down the cascade fails the
    gate — its number is not the engine it is labeled with."""
    g = _graph()
    g["engines"]["pallas_mega"]["counters"]["fallback.count"] = 2
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any(
        "pallas_mega" in m and "fallback.count = 2" in m for m in msgs
    )


def test_missing_fallback_counter_fails():
    """Dropping the counter (e.g. running with on_plan_failure='raise')
    fails loudly rather than passing vacuously."""
    g = _graph()
    del g["engines"]["pallas_waves"]["counters"]["fallback.count"]
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any(
        "pallas_waves" in m and "no fallback.count" in m for m in msgs
    )


def test_non_pallas_rows_exempt_from_fallback_counter():
    """The XLA/rounds engines have no cascade; the guard gate only
    inspects pallas_* rows."""
    g = _graph()
    assert "fallback.count" not in g["engines"]["waves_xla"]["counters"]
    ok, _ = check_report(_report([g]))
    assert ok


def test_snapshot_overhead_gate_boundary_is_inclusive():
    """Gate 7: overhead exactly at the target passes, above fails with a
    message naming the scale and the measured percentage."""
    g = _graph()
    g["recovery"]["snapshot_overhead_pct"] = TARGET_SNAPSHOT_OVERHEAD_PCT
    ok, _ = check_report(_report([g]))
    assert ok
    g["recovery"]["snapshot_overhead_pct"] = TARGET_SNAPSHOT_OVERHEAD_PCT + 0.01
    ok, msgs = check_report(_report([g]))
    assert not ok
    msg = next(m for m in msgs if "recovery" in m and m.startswith("FAIL"))
    assert "snapshot overhead" in msg and "scale 10" in msg


def test_non_bit_exact_resume_fails():
    """Gate 7: a resumed result that diverged from the one-shot run is a
    correctness failure, whatever the overhead says."""
    g = _graph()
    g["recovery"]["resumed_bit_exact"] = False
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any("not bit-exact" in m for m in msgs)


def test_clean_path_retries_fail():
    """Gate 7: the guard firing on an uninjected run means the engines
    are flaky (or the guard misclassifies) — never acceptable."""
    g = _graph()
    g["recovery"]["clean_retries"] = 2
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any("clean_retries = 2" in m for m in msgs)


def test_missing_recovery_block_fails():
    """Gate 7 fails loudly when the recovery block (or its gated
    overhead field) is missing — a bench refactor that stops measuring
    the resumable path cannot pass vacuously."""
    g = _graph()
    del g["recovery"]
    ok, msgs = check_report(_report([g]))
    assert not ok
    assert any("no recovery block" in m for m in msgs)
    g2 = _graph()
    del g2["recovery"]["snapshot_overhead_pct"]
    ok, msgs = check_report(_report([g2]))
    assert not ok
    assert any("no snapshot_overhead_pct" in m for m in msgs)


def test_recovery_gate_enforced_on_every_graph():
    """A single over-budget graph fails even when the others pass."""
    g10, g12 = _graph(10), _graph(12)
    g12["recovery"]["snapshot_overhead_pct"] = 40.0
    ok, msgs = check_report(_report([g10, g12]))
    assert not ok
    msg = next(m for m in msgs if "recovery" in m and m.startswith("FAIL"))
    assert "scale 12" in msg and "scale 10" not in msg


def test_check_exits_nonzero_with_message(monkeypatch, capsys):
    """CLI wiring: `--check` on a failing report exits non-zero via
    SystemExit with a message, after printing each gate verdict — no
    bare assert anywhere on the path. The bench itself is stubbed out
    (run_report monkeypatched) so this stays a unit test."""
    import benchmarks.bench_throughput as bt

    bad = _report([_graph(10, mega=0.5)])
    monkeypatch.setattr(
        bt, "run_report", lambda **kw: ([("row", 1.0, "derived")], bad)
    )
    monkeypatch.setattr(
        sys, "argv", ["bench_throughput", "--check", "--no-json"]
    )
    with pytest.raises(SystemExit) as exc:
        bt.main()
    assert exc.value.code not in (0, None)
    assert "bench gate FAILED" in str(exc.value.code)
    out = capsys.readouterr().out
    assert "# gate:" in out and "FAIL" in out

    good = _report([_graph(10)])
    monkeypatch.setattr(
        bt, "run_report", lambda **kw: ([("row", 1.0, "derived")], good)
    )
    bt.main()  # all gates pass: returns normally, prints PASS lines
    out = capsys.readouterr().out
    assert "PASS" in out and "FAIL" not in out


def test_trace_flag_writes_chrome_trace(monkeypatch, capsys, tmp_path):
    """CLI wiring: `--trace out.json` dumps the session's Chrome trace."""
    import benchmarks.bench_throughput as bt

    good = _report([_graph(10)])
    monkeypatch.setattr(
        bt, "run_report", lambda **kw: ([("row", 1.0, "derived")], good)
    )
    out_path = tmp_path / "trace.json"
    monkeypatch.setattr(
        sys, "argv", ["bench_throughput", "--no-json", "--trace", str(out_path)]
    )
    bt.main()
    trace = json.loads(out_path.read_text())
    assert "traceEvents" in trace and isinstance(trace["traceEvents"], list)


def test_committed_bench_record_passes_gate():
    """The repo's committed BENCH_substream.json satisfies its own gate
    (including mega >= waves_xla at every recorded scale AND the
    telemetry stage/counter gates)."""
    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_substream.json"
    report = json.loads(path.read_text())
    ok, msgs = check_report(report)
    assert ok, msgs
