"""Multi-device integration (8 forced host devices, subprocess because the
device count must be fixed before jax initializes)."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    # 1) distributed matching rounds == sequential oracle
    from repro.core import EdgeStream, SubstreamConfig, mwm_scan, mwm_rounds_sharded
    rng = np.random.default_rng(1)
    n, L = 64, 16
    cfg = SubstreamConfig(n=n, L=L, eps=0.15)
    src = rng.integers(0, n, 248); dst = rng.integers(0, n, 248)
    w = rng.uniform(1.0, cfg.w_max, 248).astype(np.float32)
    s = EdgeStream.from_numpy(src, dst, w, n_pad=256)
    res = mwm_scan(s, cfg)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    a, mb = mwm_rounds_sharded(s, cfg, mesh)
    assert (np.asarray(a) == np.asarray(res.assigned)).all(), "assigned mismatch"
    assert (np.asarray(mb) == np.asarray(res.mb)).all(), "mb mismatch"

    # 2) tiny sharded LM train step on a 4x2 mesh + elastic restore on 2x2
    import dataclasses
    from repro.configs import get_arch
    from repro.models import transformer as tfm
    from repro.models.param import init_params, pspecs
    from repro.optim import AdamWConfig, adamw_init, adamw_update
    from repro.checkpoint import CheckpointManager

    arch = get_arch("gemma-7b")
    cfg2 = dataclasses.replace(arch.smoke_config, param_dtype=jnp.float32,
                               vocab_pad_to=8)
    params = init_params(tfm.param_specs(cfg2), jax.random.key(0))
    rules = {"dp": ("data",), "embed": None, "heads": "model",
             "kv_heads": "model", "mlp": "model", "vocab": "model",
             "layers": None, "model_seq": None}
    ps = pspecs(tfm.param_specs(cfg2), rules)
    shardings = jax.tree_util.tree_map(lambda p: NamedSharding(mesh, p), ps,
        is_leaf=lambda x: isinstance(x, P))
    params = jax.tree_util.tree_map(jax.device_put, params, shardings)
    opt_cfg = AdamWConfig(lr=1e-2)
    opt = adamw_init(params, opt_cfg)
    toks = jax.device_put(
        jax.random.randint(jax.random.key(1), (4, 64), 0, cfg2.vocab),
        NamedSharding(mesh, P("data", None)))

    @jax.jit
    def step(params, opt, toks):
        loss, g = jax.value_and_grad(lambda p: tfm.loss_fn(p, toks, cfg2))(params)
        params, opt, _ = adamw_update(params, g, opt, opt_cfg.lr, opt_cfg)
        return params, opt, loss

    with mesh:
        losses = []
        for _ in range(5):
            params, opt, loss = step(params, opt, toks)
            losses.append(float(loss))
    assert losses[-1] < losses[0], f"loss did not decrease: {losses}"

    # checkpoint on 4x2, restore on 2x2 (elastic remesh)
    import tempfile
    d = tempfile.mkdtemp()
    mgr = CheckpointManager(d, async_save=False)
    mgr.save(5, {"params": params})
    mesh2 = jax.make_mesh((2, 2), ("data", "model"))
    shardings2 = jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh2, p), ps, is_leaf=lambda x: isinstance(x, P))
    import repro.models.param as mp
    step_r, restored = mgr.restore(
        {"params": mp.abstract_params(tfm.param_specs(cfg2))},
        shardings={"params": shardings2})
    assert step_r == 5
    w_old = np.asarray(params["lm_head"])
    w_new = np.asarray(restored["params"]["lm_head"])
    assert np.allclose(w_old, w_new), "elastic restore changed weights"

    # 3) recsys sharded_topk correctness under a sharded vocab
    from repro.launch.steps import sharded_topk
    scores = jax.device_put(
        jax.random.normal(jax.random.key(3), (4, 64)),
        NamedSharding(mesh2, P(None, "model")))
    with mesh2:
        v, i = jax.jit(lambda s: sharded_topk(s, k=5, shards=4))(scores)
    ref_i = np.argsort(-np.asarray(scores), axis=1)[:, :5]
    ref_v = np.take_along_axis(np.asarray(scores), ref_i, axis=1)
    assert np.allclose(np.sort(np.asarray(v))[:, ::-1], ref_v, atol=1e-6)
    print("MULTIDEVICE_OK")
    """
)


@pytest.mark.slow
def test_multidevice_integration():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MULTIDEVICE_OK" in proc.stdout
