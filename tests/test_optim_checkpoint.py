"""Optimizer, schedules, compression, checkpointing, fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree
from repro.distributed.elastic import plan_remesh
from repro.distributed.straggler import StragglerMonitor
from repro.optim import (
    AdamWConfig,
    ErrorFeedback,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_int8,
    decompress_int8,
    cosine_schedule,
    wsd_schedule,
)


def test_adamw_first_step_matches_reference():
    cfg = AdamWConfig(lr=0.1, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                      grad_clip=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    opt = adamw_init(p, cfg)
    new_p, new_opt, _ = adamw_update(p, g, opt, cfg.lr, cfg)
    # bias-corrected first step == -lr * g/|g| (elementwise sign-ish)
    expect = np.asarray([1.0, -2.0]) - 0.1 * 0.5 / (0.5 + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), expect, rtol=1e-5)
    assert int(new_opt["count"]) == 1


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0)
    p = {"w": jnp.ones(8) * 3}
    opt = adamw_init(p, cfg)
    loss = lambda p: (p["w"] ** 2).sum()
    for _ in range(200):
        g = jax.grad(loss)(p)
        p, opt, _ = adamw_update(p, g, opt, cfg.lr, cfg)
    assert float(loss(p)) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10}
    clipped, norm = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(norm), 20.0, rtol=1e-5)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped["a"])), 1.0, rtol=1e-5
    )


def test_wsd_schedule_phases():
    lr = lambda s: float(wsd_schedule(s, 1.0, warmup=10, stable=50, decay=40))
    assert lr(0) == 0
    assert abs(lr(10) - 1.0) < 1e-6
    assert abs(lr(40) - 1.0) < 1e-6  # stable leg
    assert lr(80) < lr(62) < 1.0  # decaying
    assert abs(lr(100) - 0.1) < 1e-2  # final_frac
    assert cosine_schedule(1000, 1.0, 10, 1000) <= 0.11


def test_int8_compression_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(33, 7)).astype(np.float32))
    q, scale, shape = compress_int8(x)
    back = decompress_int8(q, scale, shape)
    assert back.shape == x.shape
    rel = np.abs(np.asarray(back) - np.asarray(x)).max() / np.abs(np.asarray(x)).max()
    assert rel < 0.02  # 1/127 block quantization


def test_error_feedback_preserves_signal():
    """EF residual carries quantization error -> running sum stays faithful."""
    rng = np.random.default_rng(1)
    true = rng.normal(size=64).astype(np.float32) * 1e-3
    resid = jnp.zeros(64)
    acc_q = np.zeros(64)
    for _ in range(50):
        q, scale, shape, resid = ErrorFeedback.compress_with_feedback(
            jnp.asarray(true), resid
        )
        acc_q += np.asarray(decompress_int8(q, scale, shape))
    np.testing.assert_allclose(acc_q / 50, true, rtol=0.05, atol=1e-6)


def test_checkpoint_roundtrip_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"params": {"w": jnp.arange(6.0).reshape(2, 3)},
             "opt": {"m": jnp.zeros(3), "count": jnp.int32(7)}}
    for step in (1, 2, 3):
        mgr.save(step, state, metadata={"loss": 1.0 / step})
    assert mgr.all_steps() == [2, 3]  # retention
    step, restored = mgr.restore(
        {"params": state["params"], "opt": state["opt"]}
    )
    assert step == 3
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]), np.asarray(state["params"]["w"])
    )
    assert int(restored["opt"]["count"]) == 7


def test_checkpoint_atomicity(tmp_path):
    """A stale .tmp dir (simulated crash) is never listed as a checkpoint."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    os.makedirs(tmp_path / "step_00000009.tmp")
    assert mgr.all_steps() == []
    mgr.save(1, {"p": {"w": jnp.zeros(2)}})
    assert mgr.all_steps() == [1]


def test_straggler_monitor_flags_outlier():
    mon = StragglerMonitor(threshold=2.0, warmup_steps=3)
    events = [mon.observe(0.1) for _ in range(10)]
    assert all(e is None for e in events)
    ev = mon.observe(0.5)
    assert ev is not None and ev.ratio > 2.0
    # outlier did not poison the EWMA
    assert abs(mon.ewma - 0.1) < 0.02


def test_plan_remesh_shrinks_cleanly():
    plan = plan_remesh(256, prefer_model=16)
    assert plan.data == 16 and plan.model == 16
    plan = plan_remesh(240, prefer_model=16)  # lost one host of 16
    assert plan.n_devices <= 240 and plan.model in (16, 8, 4, 2, 1)
    plan = plan_remesh(3, prefer_model=16)
    assert plan.n_devices <= 3 and plan.n_devices >= 2
