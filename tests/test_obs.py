"""Tests for the telemetry subsystem (`repro.obs`).

Three invariant families:

* **tracer/schema** — spans nest by interval containment and the export
  is valid Chrome trace-event JSON (complete events with μs ts/dur,
  instant events with scope), so Perfetto opens it;
* **zero-overhead disabled path** — `obs.DISABLED` hands out the same
  shared no-op objects by identity and the hot loop neither records nor
  accumulates allocations;
* **record consistency** — per-engine stage seconds are disjoint
  subintervals of the call wall time, the counters are bit-exact copies
  of the WavePlan / mega_plan / WaveSchedule accounting, and repeated
  runs produce identical counters (modulo the jit hit/miss labels,
  which legitimately flip between a cold and a warm call).
"""
import json
import time
import tracemalloc

import jax
import numpy as np
import pytest

from repro import obs
from repro.core import merge, rounds
from repro.core.matching import mwm_waves
from repro.core.types import EdgeStream, SubstreamConfig
from repro.graph.waves import (
    block_aligned_layout,
    schedule_counters,
    wave_schedule,
)
from repro.kernels.substream_match.ops import (
    MEGA_SEG_BLOCK,
    mega_plan,
    substream_match,
    traffic_bytes,
    wave_plan,
)


def _round_up(x, mult):
    return ((x + mult - 1) // mult) * mult


def _workload(m=600, n=128, L=8, eps=0.1, seed=0):
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, m).astype(np.int32)
    dst = rng.integers(0, n, m).astype(np.int32)
    w = (rng.random(m) * 10 + 1).astype(np.float32)
    stream = EdgeStream.from_numpy(src, dst, w)
    return stream, SubstreamConfig(n=n, L=L, eps=eps)


# ---------------------------------------------------------------- tracer


def test_spans_nest_by_interval_containment():
    tel = obs.Telemetry()
    with tel.span("outer"):
        with tel.span("inner"):
            time.sleep(0.001)
    evs = tel.chrome_trace()["traceEvents"]
    outer = next(e for e in evs if e["name"] == "outer")
    inner = next(e for e in evs if e["name"] == "inner")
    # inner exits first, so its [ts, ts+dur] sits inside outer's
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]
    assert inner["dur"] >= 1000  # slept 1ms; ts/dur are microseconds


def test_chrome_trace_schema_is_valid():
    tel = obs.Telemetry()
    with tel.span("a", detail=1):
        pass
    tel.event("mark", backend="cpu")
    tel.count("some.counter", 3)
    trace = tel.chrome_trace()
    # round-trips through JSON (what write_chrome_trace emits)
    trace = json.loads(json.dumps(trace))
    assert trace["displayTimeUnit"] == "ms"
    assert trace["otherData"]["counters"] == {"some.counter": 3}
    for e in trace["traceEvents"]:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0
        if e["ph"] == "i":
            assert e["s"] == "t"
    assert {e["ph"] for e in trace["traceEvents"]} == {"X", "i"}


def test_write_chrome_trace_roundtrip(tmp_path):
    tel = obs.Telemetry()
    with tel.span("s"):
        pass
    path = tmp_path / "trace.json"
    tel.write_chrome_trace(path)
    trace = json.loads(path.read_text())
    assert [e["name"] for e in trace["traceEvents"]] == ["s"]


def test_stopwatch_measures_even_when_disabled():
    with obs.stopwatch(obs.DISABLED, "x") as sw:
        time.sleep(0.001)
    assert sw.seconds >= 0.001
    tel = obs.Telemetry()
    with obs.stopwatch(tel, "x") as sw2:
        pass
    ev = tel.chrome_trace()["traceEvents"][0]
    assert ev["name"] == "x"
    assert ev["dur"] == pytest.approx(sw2.seconds * 1e6, rel=1e-9)


# ------------------------------------------------------- disabled path


def test_disabled_path_is_identity_objects():
    assert obs.DISABLED.span("a") is obs.NULL_SPAN
    assert obs.DISABLED.span("b", k=1) is obs.NULL_SPAN
    assert obs.DISABLED.counters is obs.NULL_COUNTERS
    assert obs.recorder(obs.DISABLED, "e", 10) is obs.NULL_RECORDER
    assert obs.recorder(None, "e", 10) is obs.NULL_RECORDER
    assert obs.DISABLED.match_calls == ()
    assert obs.DISABLED.events == ()
    with pytest.raises(RuntimeError):
        obs.DISABLED.write_chrome_trace("/tmp/nope.json")


def test_disabled_hot_loop_does_not_accumulate_allocations():
    """The no-op path may allocate transient call frames but must not
    retain anything per iteration (no event lists, no span objects)."""
    tel = obs.DISABLED
    rec = obs.recorder(tel, "hot", 1)
    # warm up any lazy interning before measuring
    with tel.span("hot"):
        pass
    tracemalloc.start()
    for _ in range(5000):
        with tel.span("hot"):
            pass
        tel.count("hot.counter")
        with rec.stage("layout"):
            pass
        rec.put("gauge", 1)
    current, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    # 5000 iterations retaining even one small object each would hold
    # hundreds of KiB; the no-op path must stay near-zero
    assert current < 16_384, f"disabled path retained {current} bytes"


def test_disabled_engine_results_identical():
    stream, cfg = _workload()
    tel = obs.Telemetry()
    for eng in ("edges", "waves", "mega"):
        a = substream_match(stream, cfg, schedule=eng, telemetry=tel)
        b = substream_match(stream, cfg, schedule=eng)
        np.testing.assert_array_equal(np.asarray(a.assigned), np.asarray(b.assigned))


# -------------------------------------------------- record consistency


def test_consistency_problems_unit():
    good = {"schedule": 0.1, "pack": 0.0, "layout": 0.1, "compile": 0.0,
            "execute": 0.2}
    assert obs.consistency_problems(good, 0.5) == []
    probs = obs.consistency_problems({"schedule": 0.1}, 0.5)
    assert any("missing" in p for p in probs)
    probs = obs.consistency_problems({**good, "execute": -1.0}, 0.5)
    assert any("negative" in p for p in probs)
    probs = obs.consistency_problems(good, 0.1)
    assert any("exceeds wall" in p for p in probs)


@pytest.mark.parametrize("eng", ["edges", "waves", "mega"])
def test_stage_seconds_within_wall(eng):
    stream, cfg = _workload(m=500, n=96, L=8, eps=0.12, seed=eng.__hash__() % 7)
    tel = obs.Telemetry()
    substream_match(stream, cfg, schedule=eng, telemetry=tel)
    rec = tel.match_calls[-1]
    assert rec.engine == f"pallas_{eng}"
    assert obs.consistency_problems(rec.stage_seconds, rec.wall_seconds) == []
    assert set(rec.stage_seconds) == set(obs.STAGES)


def test_compile_then_execute_labeling():
    """First dispatch of a jit variant lands in `compile`, repeats in
    `execute` — tracked process-wide, including disabled warmups."""
    stream, cfg = _workload(m=333, n=64, L=8, eps=0.17, seed=5)
    tel = obs.Telemetry()
    substream_match(stream, cfg, schedule="waves", telemetry=tel)
    cold = tel.match_calls[-1]
    substream_match(stream, cfg, schedule="waves", telemetry=tel)
    warm = tel.match_calls[-1]
    assert cold.stage_seconds["compile"] > 0 and cold.stage_seconds["execute"] == 0
    assert warm.stage_seconds["compile"] == 0 and warm.stage_seconds["execute"] > 0
    assert cold.counters["jit.variant_miss"] == 1
    assert warm.counters["jit.variant_hit"] == 1
    # a warmup made with telemetry DISABLED still marks the variant warm
    stream2, cfg2 = _workload(m=334, n=64, L=8, eps=0.17, seed=6)
    substream_match(stream2, cfg2, schedule="waves")
    tel2 = obs.Telemetry()
    substream_match(stream2, cfg2, schedule="waves", telemetry=tel2)
    assert tel2.match_calls[-1].stage_seconds["compile"] == 0


def test_wave_counters_bit_exact_against_plan():
    stream, cfg = _workload(m=700, n=160, L=8)
    src, dst = np.asarray(stream.src), np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    tel = obs.Telemetry()
    substream_match(stream, cfg, schedule="waves", telemetry=tel)
    rec = tel.match_calls[-1]
    sch = wave_schedule(src, dst, valid=valid)
    plan = wave_plan(cfg.n, cfg.L, sch)
    assert rec.counters["plan.gather_bytes"] == plan.gather_bytes
    assert rec.counters["plan.bit_block_bytes"] == plan.nbytes
    assert rec.counters["plan.seg"] == plan.seg
    assert rec.counters["plan.block_s"] == plan.block_s
    for k, v in schedule_counters(sch).items():
        assert rec.counters[k] == v, k
    total = _round_up(max(sch.num_segments, 1), plan.block_s) * plan.seg
    assert rec.counters["traffic.hbm_bytes"] == traffic_bytes(
        total, sch.num_scheduled, plan.width
    )


def test_mega_counters_bit_exact_against_plan():
    stream, cfg = _workload(m=700, n=160, L=8)
    src, dst = np.asarray(stream.src), np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    tel = obs.Telemetry()
    substream_match(stream, cfg, schedule="mega", telemetry=tel)
    rec = tel.match_calls[-1]
    sch = wave_schedule(src, dst, valid=valid)
    layout = block_aligned_layout(sch, MEGA_SEG_BLOCK)
    plan = mega_plan(cfg.n, cfg.L, layout)
    assert rec.counters["plan.gather_bytes"] == plan.gather_bytes
    assert rec.counters["plan.tile_bytes"] == plan.tile_bytes
    assert rec.counters["plan.tiles_per_block"] == plan.tiles_per_block
    assert rec.counters["layout.num_tiles"] == layout.num_tiles
    assert rec.counters["layout.padding_rows"] == (
        layout.num_segments - sch.num_segments
    )
    bslots = plan.seg_block * plan.seg
    total = _round_up(max(layout.num_tiles, 1), plan.tiles_per_block) * bslots
    assert rec.counters["traffic.hbm_bytes"] == traffic_bytes(
        total, sch.num_scheduled, plan.width
    )


def test_counters_deterministic_across_runs():
    """Re-running the same call yields identical counters, except the
    jit hit/miss labels (cold vs warm is real state, not noise)."""
    stream, cfg = _workload(m=450, n=96, L=8)

    def counters_of(eng):
        tel = obs.Telemetry()
        substream_match(stream, cfg, schedule=eng, telemetry=tel)
        return {
            k: v
            for k, v in tel.match_calls[-1].counters.items()
            if not k.startswith("jit.")
        }

    for eng in ("edges", "waves", "mega"):
        first = counters_of(eng)
        second = counters_of(eng)
        assert first == second
        assert first  # non-empty


def test_backend_event_per_call():
    """`resolve_interpret`'s auto flip is no longer silent: every
    substream_match call emits one structured backend event."""
    stream, cfg = _workload(m=200, n=64, L=8)
    tel = obs.Telemetry()
    substream_match(stream, cfg, schedule="edges", telemetry=tel)
    substream_match(stream, cfg, schedule="mega", telemetry=tel)
    evs = [e for e in tel.events if e["name"] == "substream_match.backend"]
    assert len(evs) == 2
    assert [e["engine"] for e in evs] == ["edges", "mega"]
    for e in evs:
        assert e["backend"] == jax.default_backend()
        assert isinstance(e["interpret"], bool)
        # on anything but a real TPU the auto policy interprets
        if e["backend"] != "tpu":
            assert e["interpret"] is True


def test_schedule_seconds_one_timing_path():
    """The deprecated WaveSchedule fields and the telemetry spans are
    views of the same stopwatch measurement — not two timers."""
    stream, _ = _workload(m=800, n=128, L=8)
    tel = obs.Telemetry()
    sch = wave_schedule(
        np.asarray(stream.src),
        np.asarray(stream.dst),
        valid=np.asarray(stream.valid),
        telemetry=tel,
    )
    evs = tel.chrome_trace()["traceEvents"]
    assign = next(e for e in evs if e["name"] == "wave_schedule.assign")
    pack = next(e for e in evs if e["name"] == "wave_schedule.pack")
    assert assign["dur"] == pytest.approx(sch.schedule_seconds * 1e6, rel=1e-9)
    assert pack["dur"] == pytest.approx(sch.pack_seconds * 1e6, rel=1e-9)
    # and the schedule geometry landed in the session counters
    assert tel.counters.get("schedule.num_waves") == sch.num_waves
    assert tel.counters.get("schedule.fill") == sch.fill


def test_roofline_fraction_sane():
    stream, cfg = _workload(m=600, n=128, L=8)
    tel = obs.Telemetry()
    substream_match(stream, cfg, schedule="mega", telemetry=tel)
    terms = tel.match_calls[-1].roofline()
    assert terms["bound_edges_per_s"] > 0
    assert terms["bytes_per_edge"] > 0
    assert 0 < terms["achieved_fraction"] < 1  # interpret mode is slow
    assert terms["dominant"] in ("pipeline", "memory")


def test_xla_engines_and_merge_record():
    stream, cfg = _workload(m=400, n=96, L=8)
    tel = obs.Telemetry()
    res = mwm_waves(stream, cfg, telemetry=tel)
    assert tel.match_calls[-1].engine == "waves_xla"
    rounds.mwm_rounds(stream, cfg, telemetry=tel)
    assert tel.match_calls[-1].engine == "rounds"
    for rec in tel.match_calls:
        assert obs.consistency_problems(rec.stage_seconds, rec.wall_seconds) == []
    t = merge.merge_host(stream, res, cfg, telemetry=tel)
    assert tel.counters.get("merge.recorded_edges") == int(
        (np.asarray(res.assigned) >= 0).sum()
    )
    assert tel.counters.get("merge.matched_edges") == len(t)
    names = {e["name"] for e in tel.chrome_trace()["traceEvents"]}
    assert "merge.host" in names
    merge.merge_device(stream, res, cfg, telemetry=tel)
    assert "merge.device" in {e["name"] for e in tel.chrome_trace()["traceEvents"]}


def test_match_telemetry_asdict_json_ready():
    stream, cfg = _workload(m=300, n=64, L=8)
    tel = obs.Telemetry()
    substream_match(stream, cfg, schedule="waves", telemetry=tel)
    d = tel.match_calls[-1].asdict()
    json.dumps(d)  # must serialize
    assert list(d["stage_seconds"]) == list(obs.STAGES)
    assert d["edges_per_sec"] > 0
    assert d["engine"] == "pallas_waves"
