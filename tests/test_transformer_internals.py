"""Transformer internals: attention variants, MoE fold/groups, unroll==scan."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.param import init_params
from repro.models.transformer import (
    TransformerConfig,
    _moe_ffn,
    attention,
    loss_fn,
    param_specs,
)

BASE = TransformerConfig(
    name="t", n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16, d_ff=96,
    vocab=257, attn_chunk=8, loss_chunk=16, param_dtype=jnp.float32,
)


def _naive_attention(q, k, v, causal=True):
    B, S, Hq, D = q.shape
    Hk = k.shape[2]
    G = Hq // Hk
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.reshape(B, S, Hk, G, D), k) / np.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v).reshape(B, S, Hq, D)


@pytest.mark.parametrize("par,chunk,unroll", [
    (1, 8, False), (1, 8, True), (2, 4, False), (4, 8, True), (8, 4, False),
])
def test_attention_variants_match_naive(par, chunk, unroll):
    cfg = dataclasses.replace(BASE, attn_chunk=chunk, attn_par=par, unroll=unroll)
    B, S, Hq, Hk, D = 2, 32, 4, 2, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hk, D))
    v = jax.random.normal(ks[2], (B, S, Hk, D))
    got = attention(q, k, v, cfg)
    want = _naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_loss_unroll_equals_scan():
    cfg_scan = dataclasses.replace(BASE, unroll=False)
    cfg_unroll = dataclasses.replace(BASE, unroll=True)
    params = init_params(param_specs(BASE), jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (2, 32), 0, BASE.vocab)
    l1 = loss_fn(params, toks, cfg_scan)
    l2 = loss_fn(params, toks, cfg_unroll)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_moe_groups_respect_capacity():
    cfg = dataclasses.replace(
        BASE, n_experts=4, top_k=2, moe_groups=4, capacity_factor=1.0
    )
    params = init_params(param_specs(cfg), jax.random.key(2))
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    x = jax.random.normal(jax.random.key(3), (64, cfg.d_model))
    out = _moe_ffn(x, lp["router"], lp["w1"], lp["w2"], cfg)
    assert out.shape == x.shape
    assert np.isfinite(np.asarray(out)).all()


def test_moe_expert_fold_equivalence():
    """fold=2 with block-partitioned weights == fold=1 exactly."""
    E, d, ff, k = 4, 32, 48, 2
    cfg1 = dataclasses.replace(
        BASE, d_model=d, d_ff=ff, n_experts=E, top_k=k, expert_fold=1,
        act="swiglu", moe_groups=2,
    )
    cfg2 = dataclasses.replace(cfg1, expert_fold=2)
    keys = jax.random.split(jax.random.key(4), 4)
    router = jax.random.normal(keys[0], (d, E))
    w1 = jax.random.normal(keys[1], (E, d, 2 * ff)) * 0.1
    w2 = jax.random.normal(keys[2], (E, ff, d)) * 0.1
    x = jax.random.normal(keys[3], (16, d))
    out1 = _moe_ffn(x, router, w1, w2, cfg1)
    # fold weights: gate/up halves split per fold, w2 rows split per fold
    g, u = jnp.split(w1, 2, axis=-1)  # [E, d, ff] each
    gs = jnp.split(g, 2, axis=-1)
    us = jnp.split(u, 2, axis=-1)
    w1f = jnp.stack(
        [jnp.concatenate([gs[f], us[f]], -1) for f in range(2)], axis=1
    ).reshape(E * 2, d, ff)
    w2f = jnp.stack(jnp.split(w2, 2, axis=1), axis=1).reshape(E * 2, ff // 2, d)
    out2 = _moe_ffn(x, router, w1f, w2f, cfg2)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=1e-5)


def test_moe_drops_overflow_tokens():
    """capacity_factor < needed -> some tokens dropped, output finite."""
    cfg = dataclasses.replace(
        BASE, n_experts=2, top_k=2, capacity_factor=0.25, moe_groups=1
    )
    params = init_params(param_specs(cfg), jax.random.key(5))
    lp = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    x = jax.random.normal(jax.random.key(6), (64, cfg.d_model))
    out = _moe_ffn(x, lp["router"], lp["w1"], lp["w2"], cfg)
    assert np.isfinite(np.asarray(out)).all()
    # with tiny capacity some token rows must be exactly zero
    assert (np.abs(np.asarray(out)).sum(axis=1) == 0).any()
