"""Property-based invariants of the grid-pipelined segment megakernel.

Random RMAT graphs with random ``seg_block`` / wave-width / ``L`` draws
must leave the megakernel bit-identical to the sequential scan, and the
double-buffered grid pipeline must never let a tile's gather observe
state from its own (or a later) tile's scatter.  The second property is
checked two ways:

* structurally — in the block-aligned layout no tile straddles a wave
  boundary and every tile is vertex-disjoint, so a one-tile-op
  gather/compute/scatter cannot race itself;
* behaviourally — a host replay that processes one tile per step,
  reading **only pre-tile state** for the whole tile, reproduces the
  interpret-mode kernel exactly.  If any double-buffered trip read a
  segment tile before the previous tile's scatter landed, the kernel
  would diverge from this replay (and from the scan).
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import EdgeStream, SubstreamConfig, mwm_scan
from repro.graph.generators import kronecker_graph, uniform_weights
from repro.graph.waves import block_aligned_layout, wave_schedule
from repro.kernels.substream_match.ops import substream_match

SETTINGS = dict(max_examples=12, deadline=None)


def _rmat_case(draw):
    scale = draw(st.integers(3, 6))
    ef = draw(st.sampled_from([1, 2, 4]))
    L = draw(st.sampled_from([1, 9, 16, 33]))
    seed = draw(st.integers(0, 2**31 - 1))
    src, dst = kronecker_graph(scale, edge_factor=ef, seed=seed)
    n = 1 << scale
    cfg = SubstreamConfig(n=n, L=L, eps=0.1)
    w = uniform_weights(src.shape[0], L, 0.1, seed=seed).astype(np.float32)
    pad = draw(st.sampled_from([0, 5]))
    stream = EdgeStream.from_numpy(src, dst, w, n_pad=src.shape[0] + pad)
    seg_block = draw(st.sampled_from([1, 2, 3, 4]))
    max_width = draw(st.sampled_from([None, 2, 8]))
    return stream, cfg, seg_block, max_width


@given(st.data())
@settings(**SETTINGS)
def test_mega_bit_identical_to_scan(data):
    """Random RMAT x random seg_block/width/L: mega == scan, bit for bit,
    in both bit layouts."""
    stream, cfg, seg_block, max_width = _rmat_case(data.draw)
    want = mwm_scan(stream, cfg)
    packed = data.draw(st.booleans())
    got = substream_match(
        stream,
        cfg,
        schedule="mega",
        seg_block=seg_block,
        max_width=max_width,
        interpret=True,
        packed=packed,
    )
    assert got.is_packed == packed
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()


def _tile_replay(layout, stream, cfg):
    """Host oracle of the pipelined tile semantics: one tile per step,
    the whole tile reads only pre-tile state, then scatters atomically.
    Well-defined only because tiles are vertex-disjoint."""
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    w = np.where(np.asarray(stream.valid), np.asarray(stream.weight), 0.0)
    thr = np.asarray(cfg.thresholds())
    L = cfg.L
    mb = np.zeros((cfg.n, L), bool)
    assigned = np.full(src.shape[0], -1, np.int32)
    sb = layout.seg_block
    for t in range(layout.num_tiles):
        rows = layout.slots[t * sb : (t + 1) * sb].reshape(-1)
        pos = rows[rows >= 0]
        u, v, wt = src[pos], dst[pos], w[pos]
        te = (wt[:, None] >= thr[None, :]) & (u != v)[:, None]
        add = te & ~mb[u] & ~mb[v]  # pre-tile state only
        mb[u] |= add
        mb[v] |= add
        hit = add.any(axis=1)
        assigned[pos] = np.where(
            hit, L - 1 - np.argmax(add[:, ::-1], axis=1), -1
        )
    return assigned, mb


@given(st.data())
@settings(**SETTINGS)
def test_mega_tiles_never_read_before_scatter(data):
    """Double-buffer safety: (a) no tile straddles a wave boundary and
    every tile is vertex-disjoint (structural race-freedom), (b) the
    interpret-mode kernel equals the atomic pre-tile-state replay."""
    stream, cfg, seg_block, max_width = _rmat_case(data.draw)
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    sch = wave_schedule(src, dst, valid=valid, max_width=max_width)
    layout = block_aligned_layout(sch, seg_block)
    # (a) structural: each tile lies inside one wave...
    offs = layout.seg_offsets
    sb = layout.seg_block
    for t in range(layout.num_tiles):
        lo, hi = t * sb, (t + 1) * sb
        wave_lo = np.searchsorted(offs, lo, side="right") - 1
        assert offs[wave_lo] <= lo and hi <= offs[wave_lo + 1], (
            f"tile {t} straddles a wave boundary"
        )
        # ...and is vertex-disjoint, so its one-op scatter cannot race
        rows = layout.slots[lo:hi].reshape(-1)
        pos = rows[rows >= 0]
        live = pos[src[pos] != dst[pos]]
        verts = np.concatenate([src[live], dst[live]])
        assert len(verts) == len(set(verts.tolist())), f"tile {t} conflict"
    # (b) behavioural: kernel == atomic tile replay == scan
    want_a, want_mb = _tile_replay(layout, stream, cfg)
    got = substream_match(
        stream, cfg, schedule="mega", waves=sch, seg_block=seg_block,
        interpret=True, packed=False,
    )
    assert (np.asarray(got.assigned) == want_a).all()
    assert (np.asarray(got.mb) == want_mb).all()
    ref = mwm_scan(stream, cfg)
    assert (want_a == np.asarray(ref.assigned)).all()
    assert (want_mb == np.asarray(ref.mb)).all()
