"""Model-level invariants: EGNN E(n)-equivariance (hypothesis over random
rotations/translations), equiformer z-rotation behavior, GIN permutation
invariance of graph readout."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import make_gnn_batch
from repro.models import egnn, equiformer_v2, gin
from repro.models.param import init_params


def _rot(axis_angles):
    a, b, c = axis_angles
    Rz = np.array([[np.cos(a), -np.sin(a), 0], [np.sin(a), np.cos(a), 0], [0, 0, 1]])
    Ry = np.array([[np.cos(b), 0, np.sin(b)], [0, 1, 0], [-np.sin(b), 0, np.cos(b)]])
    Rx = np.array([[1, 0, 0], [0, np.cos(c), -np.sin(c)], [0, np.sin(c), np.cos(c)]])
    return (Rz @ Ry @ Rx).astype(np.float32)


@given(
    st.tuples(*[st.floats(-3.1, 3.1) for _ in range(3)]),
    st.tuples(*[st.floats(-5, 5) for _ in range(3)]),
)
@settings(max_examples=10, deadline=None)
def test_egnn_en_equivariance(angles, shift):
    cfg = egnn.EGNNConfig(n_layers=2, d_hidden=16, d_in=8)
    params = init_params(egnn.param_specs(cfg), jax.random.key(0))
    batch = make_gnn_batch(24, 80, 8, d_out=1, coords=True, seed=2)
    R = jnp.asarray(_rot(angles))
    t = jnp.asarray(np.asarray(shift, np.float32))
    h1, x1 = egnn.forward(params, batch, cfg)
    rotated = dataclasses.replace(batch, coords=batch.coords @ R.T + t)
    h2, x2 = egnn.forward(params, rotated, cfg)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=2e-3)
    np.testing.assert_allclose(
        np.asarray(x1 @ R.T + t), np.asarray(x2), atol=2e-3
    )


def test_equiformer_scalar_z_rotation_invariance():
    """The l=0 output channel is invariant under rotations about z (the
    exactly-implemented part of the eSCN alignment; see DESIGN.md §7)."""
    cfg = equiformer_v2.EqV2Config(n_layers=2, d_hidden=16, l_max=3, d_in=8)
    params = init_params(equiformer_v2.param_specs(cfg), jax.random.key(1))
    batch = make_gnn_batch(20, 60, 8, d_out=1, coords=True, seed=3)
    th = 1.1
    Rz = jnp.asarray(_rot((th, 0, 0)))
    out1 = equiformer_v2.forward(params, batch, cfg)
    rotated = dataclasses.replace(batch, coords=batch.coords @ Rz.T)
    out2 = equiformer_v2.forward(params, rotated, cfg)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), atol=2e-3)


def test_gin_graph_readout_permutation_invariance():
    cfg = gin.GINConfig(n_layers=2, d_hidden=16, d_in=8, n_classes=4)
    params = init_params(gin.param_specs(cfg), jax.random.key(2))
    batch = make_gnn_batch(30, 90, 8, n_classes=4, n_graphs=3, seed=4)
    logits1 = gin.graph_logits(params, batch, cfg, n_graphs=3)
    # permute node order
    perm = np.random.default_rng(5).permutation(30)
    inv = np.argsort(perm)
    import dataclasses as dc

    pb = dc.replace(
        batch,
        node_feats=batch.node_feats[perm],
        node_mask=batch.node_mask[perm],
        graph_ids=batch.graph_ids[perm],
        src=jnp.asarray(inv)[batch.src],
        dst=jnp.asarray(inv)[batch.dst],
        labels=batch.labels[perm],
        label_mask=batch.label_mask[perm],
    )
    logits2 = gin.graph_logits(params, pb, cfg, n_graphs=3)
    np.testing.assert_allclose(
        np.asarray(logits1), np.asarray(logits2), atol=1e-4
    )


def test_embedding_bag_matches_manual():
    from repro.models.embedding import embedding_bag, embedding_bag_ragged

    rng = np.random.default_rng(6)
    table = jnp.asarray(rng.normal(size=(50, 8)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, 50, (4, 6)), jnp.int32)
    valid = jnp.asarray(rng.random((4, 6)) > 0.3)
    got = embedding_bag(table, ids, mode="mean", valid=valid)
    want = np.zeros((4, 8))
    for b in range(4):
        rows = [np.asarray(table[ids[b, j]]) for j in range(6) if valid[b, j]]
        want[b] = np.mean(rows, axis=0) if rows else 0
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)
    flat = ids.reshape(-1)
    seg = jnp.repeat(jnp.arange(4), 6)
    got_r = embedding_bag_ragged(table, flat, seg, 4, mode="sum")
    want_r = np.zeros((4, 8))
    for b in range(4):
        for j in range(6):
            want_r[b] += np.asarray(table[ids[b, j]])
    np.testing.assert_allclose(np.asarray(got_r), want_r, atol=1e-4)
