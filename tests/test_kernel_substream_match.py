"""Pallas substream_match kernel: shape/dtype sweeps vs the jnp oracle,
packed (uint8 bit-plane) vs unpacked (int8 lane) layout parity, and the
VMEM plan contract."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EdgeStream,
    SubstreamConfig,
    mwm_scan,
    pack_bits,
    packed_width,
    unpack_bits,
)
from repro.kernels.substream_match.ops import (
    VMEM_BIT_BUDGET,
    max_vertices,
    substream_match,
    vmem_plan,
)
from repro.kernels.substream_match.ref import (
    substream_match_ref,
    substream_match_ref_packed,
)


def _case(n, m, L, eps, seed, wdtype=np.float32, pad=0):
    rng = np.random.default_rng(seed)
    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)  # self-loops kept on purpose
    w = rng.uniform(0.5, cfg.w_max * 1.05, m).astype(wdtype)
    return EdgeStream.from_numpy(src, dst, w, n_pad=m + pad), cfg


@pytest.mark.parametrize("packed", [True, False], ids=["packed", "unpacked"])
@pytest.mark.parametrize("n,m,L,block_e", [
    (16, 40, 1, 8),
    (100, 500, 48, 128),
    (64, 256, 64, 64),
    (257, 1000, 17, 256),  # unaligned n and L (L % 8 != 0)
    (32, 7, 128, 8),  # fewer edges than one block
])
def test_kernel_matches_scan(n, m, L, block_e, packed):
    stream, cfg = _case(n, m, L, 0.15, seed=n + m)
    want = mwm_scan(stream, cfg)
    got = substream_match(stream, cfg, block_e=block_e, interpret=True, packed=packed)
    assert got.is_packed == packed
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()


@pytest.mark.parametrize("L", [1, 7, 9, 33, 64])
def test_packed_unpacked_parity(L):
    """Bit-identical assigned + mb across layouts, incl. L % 8 != 0,
    self-loops (kept by _case) and padding edges."""
    stream, cfg = _case(48, 300, L, 0.2, seed=L, pad=29)
    got_p = substream_match(stream, cfg, block_e=64, interpret=True, packed=True)
    got_u = substream_match(stream, cfg, block_e=64, interpret=True, packed=False)
    assert (np.asarray(got_p.assigned) == np.asarray(got_u.assigned)).all()
    assert (np.asarray(got_p.mb) == np.asarray(got_u.mb)).all()
    # the packed words match an independent host-side pack of the dense bits
    assert (np.asarray(got_p.mb_packed) == np.asarray(pack_bits(got_u.mb))).all()
    assert got_p.mb_packed.shape == (cfg.n, packed_width(L))


def test_layout_follows_config_flag():
    stream, cfg = _case(20, 50, 12, 0.1, seed=5)
    assert substream_match(stream, cfg, block_e=16).is_packed
    cfg_u = SubstreamConfig(n=20, L=12, eps=0.1, mb_layout="unpacked")
    assert not substream_match(stream, cfg_u, block_e=16).is_packed
    cfg_typo = SubstreamConfig(n=20, L=12, eps=0.1, mb_layout="packd")
    with pytest.raises(ValueError, match="mb_layout"):
        substream_match(stream, cfg_typo, block_e=16)


@pytest.mark.parametrize("wdtype", [np.float32, np.float16])
def test_kernel_weight_dtypes(wdtype):
    stream, cfg = _case(48, 300, 32, 0.2, seed=7, wdtype=wdtype)
    want = mwm_scan(stream, cfg)
    got = substream_match(stream, cfg, block_e=64, interpret=True)
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()


def test_kernel_padding_edges():
    stream, cfg = _case(30, 100, 16, 0.1, seed=3, pad=57)
    want = mwm_scan(stream, cfg)
    got = substream_match(stream, cfg, block_e=32, interpret=True)
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()


def test_kernel_ref_oracle_agrees():
    stream, cfg = _case(40, 200, 24, 0.1, seed=11)
    w = jnp.where(stream.valid, stream.weight, 0.0)
    a_ref, mb_ref = substream_match_ref(
        stream.src, stream.dst, w, cfg.thresholds(), cfg.n
    )
    want = mwm_scan(stream, cfg)
    assert (np.asarray(a_ref) == np.asarray(want.assigned)).all()
    assert (np.asarray(mb_ref).astype(bool) == np.asarray(want.mb)).all()


@pytest.mark.parametrize("L", [3, 24, 33])
def test_kernel_packed_ref_oracle_agrees(L):
    """The independent packed-word scan oracle reproduces the dense oracle."""
    stream, cfg = _case(40, 200, L, 0.1, seed=11)
    w = jnp.where(stream.valid, stream.weight, 0.0)
    a_ref, mbp_ref = substream_match_ref_packed(
        stream.src, stream.dst, w, cfg.thresholds(), cfg.n
    )
    want = mwm_scan(stream, cfg)
    assert (np.asarray(a_ref) == np.asarray(want.assigned)).all()
    assert (np.asarray(unpack_bits(mbp_ref, cfg.L)) == np.asarray(want.mb)).all()


@pytest.mark.parametrize("packed", [True, False], ids=["packed", "unpacked"])
def test_vmem_budget_enforced(packed):
    cfg = SubstreamConfig(n=100_000_000, L=512, eps=0.1)
    stream, _ = _case(16, 8, 4, 0.1, seed=0)
    with pytest.raises(ValueError, match="VMEM"):
        substream_match(stream, cfg, interpret=True, packed=packed)


def test_vmem_plan_alignment():
    plan_u = vmem_plan(100, 48, packed=False)
    assert plan_u.n_pad % 8 == 0 and plan_u.width % 128 == 0
    assert plan_u.nbytes == plan_u.n_pad * plan_u.width
    plan_p = vmem_plan(100, 48, packed=True)
    assert plan_p.n_pad % 8 == 0 and plan_p.width % 8 == 0
    assert plan_p.words == packed_width(48) == 6
    assert plan_p.nbytes == plan_p.n_pad * plan_p.width
    assert plan_p.nbytes * 8 <= plan_u.nbytes


def test_vmem_plan_packed_capacity_8x():
    """Acceptance: >= 8x more vertices per core at L=64 (16x: lane padding)."""
    cap_p = max_vertices(64, packed=True)
    cap_u = max_vertices(64, packed=False)
    assert cap_p >= 8 * cap_u
    assert vmem_plan(cap_p, 64, packed=True).nbytes <= VMEM_BIT_BUDGET


def test_vmem_plan_auto_block_e():
    plan = vmem_plan(1000, 64)
    assert plan.block_e >= 128 and plan.block_e & (plan.block_e - 1) == 0
    # the bit block never starves the edge buffers (>= 4 MiB stays free),
    # so without a stream length the 8192 latency cap decides
    assert plan.block_e == 8192
    # short streams are not padded to the cap: block_e covers m snugly
    assert vmem_plan(1000, 64, m=50).block_e == 128
    assert vmem_plan(1000, 64, m=700).block_e == 1024
    assert vmem_plan(1000, 64, m=100_000).block_e == 8192


def test_auto_block_e_small_stream_end_to_end():
    """Default block_e on a tiny stream stays tiny (no 8192-pad blowup)."""
    stream, cfg = _case(16, 20, 8, 0.1, seed=2)
    want = mwm_scan(stream, cfg)
    got = substream_match(stream, cfg)  # auto block_e
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()


def test_matching_result_requires_L_for_packed():
    from repro.core import MatchingResult

    packed = pack_bits(jnp.zeros((4, 17), bool))
    with pytest.raises(ValueError, match="L is required"):
        MatchingResult(assigned=jnp.zeros(3, jnp.int32), mb_packed=packed)
    ok = MatchingResult(assigned=jnp.zeros(3, jnp.int32), mb_packed=packed, L=17)
    assert ok.mb.shape == (4, 17)


@pytest.mark.parametrize("L", [1, 8, 13, 64])
def test_bitpack_roundtrip(L):
    rng = np.random.default_rng(L)
    mb = rng.integers(0, 2, (37, L)).astype(bool)
    packed = pack_bits(jnp.asarray(mb))
    assert packed.dtype == jnp.uint8
    assert packed.shape == (37, packed_width(L))
    assert (np.asarray(unpack_bits(packed, L)) == mb).all()
    # padding bits of the last byte stay zero
    if L % 8:
        assert not (np.asarray(packed[:, -1]) >> (L % 8)).any()
