"""Pallas substream_match kernel: shape/dtype sweeps vs the jnp oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EdgeStream, SubstreamConfig, mwm_scan
from repro.kernels.substream_match.ops import substream_match, vmem_plan
from repro.kernels.substream_match.ref import substream_match_ref


def _case(n, m, L, eps, seed, wdtype=np.float32, pad=0):
    rng = np.random.default_rng(seed)
    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)  # self-loops kept on purpose
    w = rng.uniform(0.5, cfg.w_max * 1.05, m).astype(wdtype)
    return EdgeStream.from_numpy(src, dst, w, n_pad=m + pad), cfg


@pytest.mark.parametrize("n,m,L,block_e", [
    (16, 40, 1, 8),
    (100, 500, 48, 128),
    (64, 256, 64, 64),
    (257, 1000, 17, 256),  # unaligned n and L
    (32, 7, 128, 8),  # fewer edges than one block
])
def test_kernel_matches_scan(n, m, L, block_e):
    stream, cfg = _case(n, m, L, 0.15, seed=n + m)
    want = mwm_scan(stream, cfg)
    got = substream_match(stream, cfg, block_e=block_e, interpret=True)
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()
    assert (np.asarray(got.mb) == np.asarray(want.mb)).all()


@pytest.mark.parametrize("wdtype", [np.float32, np.float16])
def test_kernel_weight_dtypes(wdtype):
    stream, cfg = _case(48, 300, 32, 0.2, seed=7, wdtype=wdtype)
    want = mwm_scan(stream, cfg)
    got = substream_match(stream, cfg, block_e=64, interpret=True)
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()


def test_kernel_padding_edges():
    stream, cfg = _case(30, 100, 16, 0.1, seed=3, pad=57)
    want = mwm_scan(stream, cfg)
    got = substream_match(stream, cfg, block_e=32, interpret=True)
    assert (np.asarray(got.assigned) == np.asarray(want.assigned)).all()


def test_kernel_ref_oracle_agrees():
    stream, cfg = _case(40, 200, 24, 0.1, seed=11)
    w = jnp.where(stream.valid, stream.weight, 0.0)
    a_ref, mb_ref = substream_match_ref(
        stream.src, stream.dst, w, cfg.thresholds(), cfg.n
    )
    want = mwm_scan(stream, cfg)
    assert (np.asarray(a_ref) == np.asarray(want.assigned)).all()
    assert (np.asarray(mb_ref).astype(bool) == np.asarray(want.mb)).all()


def test_vmem_budget_enforced():
    cfg = SubstreamConfig(n=10_000_000, L=512, eps=0.1)
    stream, _ = _case(16, 8, 4, 0.1, seed=0)
    with pytest.raises(ValueError, match="VMEM"):
        substream_match(stream, cfg, interpret=True)


def test_vmem_plan_alignment():
    n_pad, L_pad, nbytes = vmem_plan(100, 48)
    assert n_pad % 8 == 0 and L_pad % 128 == 0
    assert nbytes == n_pad * L_pad
