"""Graph substrate: CSR layouts, generators, sampler, segment ops, coarsen."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.graph import (
    CSRGraph,
    CustomCSR,
    NeighborSampler,
    coarsen_by_matching,
    kronecker_graph,
    segment_softmax,
    uniform_weights,
)
from repro.core import EdgeStream, lexicographic_order


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_custom_csr_roundtrip(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 50))
    m = int(rng.integers(0, 200))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    w = rng.uniform(1, 10, m).astype(np.float32)
    csr = CSRGraph.from_edges(src, dst, w, n=n)
    cc = CustomCSR.encode(csr)
    back = cc.decode()
    assert (back.row == csr.row).all()
    assert (back.col == csr.col).all()
    assert np.allclose(back.val, csr.val)


def test_custom_csr_chunk_layout():
    """Byte-level invariants of the paper's §4.3 format."""
    rng = np.random.default_rng(0)
    csr = CSRGraph.from_edges(
        rng.integers(0, 11, 40), rng.integers(0, 11, 40),
        rng.uniform(1, 5, 40).astype(np.float32), n=11,
    )
    cc = CustomCSR.encode(csr)
    assert cc.pointer_data.nbytes % 64 == 0  # whole 512-bit chunks
    assert cc.graph_data.nbytes % 64 == 0
    assert cc.pointer_data.nbytes == -(-11 // 5) * 64  # 5 entries/chunk
    assert cc.read_requests_per_edge() == 1.125  # §5.11 model


def test_kronecker_properties():
    src, dst = kronecker_graph(8, edge_factor=8, seed=1)
    assert (src != dst).all()
    n = 256
    key = np.minimum(src, dst) * n + np.maximum(src, dst)
    assert len(np.unique(key)) == len(key)  # deduped
    s2, d2 = kronecker_graph(8, edge_factor=8, seed=1)
    assert (s2 == src).all() and (d2 == dst).all()  # deterministic


def test_lexicographic_order_is_paper_order():
    rng = np.random.default_rng(2)
    src = rng.integers(0, 40, 200)
    dst = rng.integers(0, 40, 200)
    w = rng.uniform(1, 4, 200).astype(np.float32)
    stream = EdgeStream.from_numpy(src, dst, w, n_pad=220)
    K = 8
    order = np.asarray(lexicographic_order(stream, K))
    u = np.asarray(stream.src)[order]
    v = np.asarray(stream.dst)[order]
    ok = np.asarray(stream.valid)[order]
    m = ok.sum()
    assert not ok[m:].any()  # padding last
    keys = list(zip((u[:m] // K).tolist(), v[:m].tolist(), u[:m].tolist()))
    assert keys == sorted(keys)


def test_neighbor_sampler_fanout_and_validity():
    rng = np.random.default_rng(3)
    src, dst = kronecker_graph(9, edge_factor=8, seed=4)
    w = uniform_weights(len(src), 8, 0.1)
    csr = CSRGraph.from_edges(src, dst, w, n=512, symmetrize=True)
    sampler = NeighborSampler(csr, [5, 3], seed=0)
    seeds = rng.integers(0, 512, 16)
    blocks = sampler.sample(seeds)
    assert len(blocks) == 2
    for b, fanout, nd in zip(blocks, [5, 3], [16, None]):
        assert b.dst_index.shape == b.src_index.shape
        assert b.src_index.shape[0] == b.num_dst * fanout
        # sampled edges are real graph edges
        for e in np.nonzero(b.edge_mask)[0][:50]:
            u_global = b.nodes[b.src_index[e]]
            # dst nodes are the first entries of the node table... dst idx is
            # into the *frontier* of this hop
            assert b.src_index[e] < len(b.nodes)


def test_segment_softmax_normalizes():
    scores = jnp.asarray(np.random.default_rng(5).normal(size=64), jnp.float32)
    ids = jnp.asarray(np.random.default_rng(6).integers(0, 8, 64), jnp.int32)
    p = segment_softmax(scores, ids, 8)
    sums = np.zeros(8)
    np.add.at(sums, np.asarray(ids), np.asarray(p))
    present = np.isin(np.arange(8), np.asarray(ids))
    assert np.allclose(sums[present], 1.0, atol=1e-5)


def test_coarsen_by_matching_contracts():
    src, dst = kronecker_graph(8, edge_factor=8, seed=7)
    w = uniform_weights(len(src), 16, 0.1, seed=7)
    mapping, cs, cd, cw = coarsen_by_matching(src, dst, w, n=256, L=16)
    n_coarse = mapping.max() + 1
    assert n_coarse < 256  # something contracted
    assert (cs != cd).all()  # no self loops in coarse graph
    assert cw.sum() <= w.sum() + 1e-3  # only intra-cluster weight removed
