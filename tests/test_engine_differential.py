"""Cross-engine differential harness — the standing gate for kernel work.

Six engines claim bit-identical Part-1 semantics:

* ``scan``         — `mwm_scan`, the sequential Listing-1 baseline;
* ``ref``          — the pure-jnp kernel oracle (`substream_match_ref`);
* ``pallas_edges`` — the 1-edge-per-iteration Pallas processor;
* ``pallas_waves`` — the wave-vectorized Pallas processor;
* ``mega``         — the grid-pipelined segment megakernel;
* ``waves_xla``    — the plain-XLA wave parity oracle (`mwm_waves`).

Every engine runs on a shared zoo of adversarial graphs (empty stream,
single edge, self-loops, duplicate edges, star/hub, bipartite, L % 8 != 0,
n not a multiple of the block size, padding tails) and must reproduce the
scan baseline's ``assigned`` and ``mb`` exactly — no tolerance, bit for
bit.  The merged weight additionally has to stay within the paper's
approximation guarantee against the exact (blossom) optimum.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EdgeStream,
    SubstreamConfig,
    exact_mwm_weight,
    matching_weight,
    merge_host,
    mwm_scan,
    mwm_waves,
)
from repro.kernels.substream_match.ops import substream_match
from repro.kernels.substream_match.ref import substream_match_ref


# ---------------------------------------------------------------------------
# Adversarial graph zoo
# ---------------------------------------------------------------------------


def _from_lists(n, edges, L=16, eps=0.1, pad=0):
    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    if edges:
        src, dst, w = (np.asarray(x) for x in zip(*edges))
    else:
        src = dst = np.zeros(0, np.int32)
        w = np.zeros(0, np.float32)
    stream = EdgeStream.from_numpy(src, dst, w, n_pad=src.shape[0] + pad)
    return stream, cfg


def _zoo_empty():
    return _from_lists(8, [])


def _zoo_single_edge():
    return _from_lists(5, [(1, 3, 2.5)])


def _zoo_self_loops():
    # every edge a self-loop except one real edge buried in the middle
    edges = [(i % 6, i % 6, 3.0 + i) for i in range(9)]
    edges.insert(4, (0, 5, 4.0))
    return _from_lists(6, edges)


def _zoo_duplicates():
    # the same edge many times, with ties and near-ties in weight
    edges = [(2, 7, 5.0)] * 6 + [(7, 2, 5.0)] * 3 + [(2, 7, 1.5), (1, 2, 5.0)]
    return _from_lists(9, edges, L=9)  # L % 8 != 0 on top


def _zoo_star():
    # hub 0: only one incident edge can ever match per substream
    rng = np.random.default_rng(3)
    edges = [(0, i, float(w)) for i, w in zip(range(1, 33), rng.uniform(1, 30, 32))]
    return _from_lists(33, edges, L=24)


def _zoo_bipartite():
    rng = np.random.default_rng(7)
    left = rng.integers(0, 16, 120)
    right = rng.integers(16, 32, 120)
    w = rng.uniform(1.0, 25.0, 120).astype(np.float32)
    return _from_lists(32, list(zip(left, right, w)), L=32, pad=13)


def _zoo_unaligned_L():
    rng = np.random.default_rng(11)
    src = rng.integers(0, 37, 90)
    dst = rng.integers(0, 37, 90)  # self-loops + duplicates allowed
    w = rng.uniform(0.5, 40.0, 90).astype(np.float32)
    return _from_lists(37, list(zip(src, dst, w)), L=13)


def _zoo_unaligned_n():
    # n=257 (not a multiple of 8 or any block size), m prime
    rng = np.random.default_rng(13)
    src = rng.integers(0, 257, 211)
    dst = rng.integers(0, 257, 211)
    w = rng.uniform(1.0, 60.0, 211).astype(np.float32)
    return _from_lists(257, list(zip(src, dst, w)), L=17, pad=5)


def _zoo_dense_small():
    # dense graph: long waves, lots of conflicts, weight ties
    edges = [
        (u, v, float(1 + ((u * 7 + v) % 5)))
        for u in range(10)
        for v in range(10)
        if u != v
    ]
    return _from_lists(10, edges, L=8)


ZOO = {
    "empty": _zoo_empty,
    "single_edge": _zoo_single_edge,
    "self_loops": _zoo_self_loops,
    "duplicates": _zoo_duplicates,
    "star": _zoo_star,
    "bipartite": _zoo_bipartite,
    "unaligned_L": _zoo_unaligned_L,
    "unaligned_n": _zoo_unaligned_n,
    "dense_small": _zoo_dense_small,
}


# ---------------------------------------------------------------------------
# Engines: (stream, cfg) -> (assigned int32 [m], mb bool [n, L])
# ---------------------------------------------------------------------------


def _run_scan(stream, cfg):
    r = mwm_scan(stream, cfg)
    return np.asarray(r.assigned), np.asarray(r.mb)


def _run_ref(stream, cfg):
    w = jnp.where(stream.valid, stream.weight, 0.0)
    a, mb = substream_match_ref(stream.src, stream.dst, w, cfg.thresholds(), cfg.n)
    return np.asarray(a), np.asarray(mb).astype(bool)


def _run_waves_xla(stream, cfg):
    r = mwm_waves(stream, cfg)
    return np.asarray(r.assigned), np.asarray(r.mb)


def _run_pallas(schedule):
    def run(stream, cfg):
        r = substream_match(stream, cfg, interpret=True, schedule=schedule)
        return np.asarray(r.assigned), np.asarray(r.mb)

    return run


ENGINES = {
    "ref": _run_ref,
    "pallas_edges": _run_pallas("edges"),
    "pallas_waves": _run_pallas("waves"),
    "mega": _run_pallas("mega"),
    "waves_xla": _run_waves_xla,
}


# ---------------------------------------------------------------------------
# Differential assertions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", sorted(ENGINES))
@pytest.mark.parametrize("graph", sorted(ZOO))
def test_engine_bit_identical(graph, engine):
    """Every engine reproduces the scan baseline bit for bit."""
    stream, cfg = ZOO[graph]()
    want_a, want_mb = _run_scan(stream, cfg)
    got_a, got_mb = ENGINES[engine](stream, cfg)
    assert got_a.shape == want_a.shape
    assert got_mb.shape == want_mb.shape == (cfg.n, cfg.L)
    assert (got_a == want_a).all(), f"{engine} diverges from scan on assigned"
    assert (got_mb == want_mb).all(), f"{engine} diverges from scan on mb"


@pytest.mark.parametrize("graph", sorted(ZOO))
def test_merged_weight_within_bound(graph):
    """Merged weight stays within the approximation guarantee vs exact.

    Per substream the greedy matching is (2+eps)-competitive; the
    Part-2 greedy merge loses at most another factor 2, so the composed
    Crouch–Stubbs bound the pipeline must honour is w(M*)/w(T) <= 4+eps
    (the repo-wide guarantee also asserted by test_matching_properties).
    Since every engine is bit-identical to scan (previous test), checking
    the bound once on the scan result covers all of them.
    """
    stream, cfg = ZOO[graph]()
    result = mwm_scan(stream, cfg)
    idx = merge_host(stream, result, cfg)
    weight = matching_weight(stream, idx)
    exact = exact_mwm_weight(stream)
    if exact == 0:
        assert weight == 0
    else:
        assert weight > 0
        assert exact / weight <= 4 + cfg.eps + 1e-3


def test_zoo_covers_required_adversaries():
    """The zoo actually contains what the harness claims it contains."""
    streams = {name: fn() for name, fn in ZOO.items()}
    # empty graph
    assert int(np.asarray(streams["empty"][0].valid).sum()) == 0
    # single edge
    assert int(np.asarray(streams["single_edge"][0].valid).sum()) == 1
    # self-loops present
    s, _ = streams["self_loops"]
    assert (np.asarray(s.src) == np.asarray(s.dst)).any()
    # duplicate edges present
    s, _ = streams["duplicates"]
    pairs = list(zip(np.asarray(s.src).tolist(), np.asarray(s.dst).tolist()))
    assert len(pairs) != len(set(pairs))
    # star: one hub touches every edge
    s, _ = streams["star"]
    assert (np.asarray(s.src) == 0).all()
    # bipartite: no edge inside either side
    s, _ = streams["bipartite"]
    src, dst, ok = (np.asarray(x) for x in (s.src, s.dst, s.valid))
    assert ((src[ok] < 16) & (dst[ok] >= 16)).all()
    # unaligned L and n
    assert streams["unaligned_L"][1].L % 8 != 0
    assert streams["unaligned_n"][1].n % 8 != 0
