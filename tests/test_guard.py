"""Adversarial-input zoo for the guard layer (`repro.core.guard`).

Dirty streams — NaN/Inf/negative weights, ids ≥ n, the sacrificial-row
(``n_pad``) collision, duplicate and self-loop floods, empty streams —
are sanitized and then run through **every** Part-1 engine, which must
agree bit-for-bit with the scan baseline on the repaired stream (and
with a manually cleaned stream). Also pins the `from_numpy` cast guards
(satellite: no more silent int64 wrap / NaN propagation), the
m == 0 / all-dropped / n == 0 degenerate paths, and the shape of
`ValidationReport` counters the bench embeds.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    EdgeStream,
    StreamValidationError,
    SubstreamConfig,
    check_matching,
    exact_mwm_weight,
    matching_weight,
    merge_device,
    merge_host,
    mwm_scan,
    mwm_waves,
    validate_stream,
)
from repro.core.guard import stream_problems
from repro.kernels.substream_match.ops import substream_match
from repro.kernels.substream_match.ref import substream_match_ref
from repro.testing.faultline import sacrificial_row


def _dirty(n, src, dst, w, L=12, pad=0):
    """Build a stream letting dirt through (policy='off'), plus its cfg."""
    stream = EdgeStream.from_numpy(
        np.asarray(src), np.asarray(dst), np.asarray(w),
        n_pad=len(src) + pad, policy="off",
    )
    return stream, SubstreamConfig(n=n, L=L)


def _zoo_nan_weights():
    rng = np.random.default_rng(21)
    w = rng.uniform(0.5, 6.0, 60)
    w[::7] = np.nan
    return _dirty(24, rng.integers(0, 24, 60), rng.integers(0, 24, 60), w)


def _zoo_inf_weights():
    rng = np.random.default_rng(22)
    w = rng.uniform(0.5, 6.0, 60)
    w[3] = np.inf
    w[10] = -np.inf
    return _dirty(24, rng.integers(0, 24, 60), rng.integers(0, 24, 60), w)


def _zoo_negative_weights():
    rng = np.random.default_rng(23)
    w = rng.uniform(0.5, 6.0, 60)
    w[5::11] = -2.25
    return _dirty(24, rng.integers(0, 24, 60), rng.integers(0, 24, 60), w)


def _zoo_ids_past_n():
    rng = np.random.default_rng(24)
    src = rng.integers(0, 24, 60)
    dst = rng.integers(0, 24, 60)
    src[4] = 24          # == n: the first silently-clamped row
    dst[9] = 1_000_000   # far out of range
    src[17] = -3
    return _dirty(24, src, dst, rng.uniform(0.5, 6.0, 60))


def _zoo_sacrificial_collision():
    # ids at n_pad — the padding row the row-addressed kernels scatter
    # padding slots to; a colliding real edge would alias it
    n = 21  # n_pad = 24 > n, so the collision row exists
    rng = np.random.default_rng(25)
    src = rng.integers(0, n, 60)
    dst = rng.integers(0, n, 60)
    dst[[2, 30]] = sacrificial_row(n)
    return _dirty(n, src, dst, rng.uniform(0.5, 6.0, 60))


def _zoo_dup_self_loop_flood():
    # degenerate but *legal* dirt: sanitize must drop nothing
    edges = [(3, 3, 9.0)] * 10 + [(1, 4, 5.0)] * 8 + [(4, 1, 5.0)] * 5
    src, dst, w = (np.asarray(x) for x in zip(*edges))
    return _dirty(8, src, dst, w, pad=3)


def _zoo_everything_at_once():
    rng = np.random.default_rng(26)
    src = rng.integers(0, 24, 80)
    dst = rng.integers(0, 24, 80)
    w = rng.uniform(0.5, 6.0, 80)
    src[0] = -1
    dst[1] = 99
    w[2] = np.nan
    w[3] = np.inf
    w[4] = -0.5
    src[5] = dst[5] = 7  # legal self-loop stays
    return _dirty(24, src, dst, w, pad=5)


def _zoo_empty():
    return _dirty(8, [], [], [])


DIRTY_ZOO = {
    "nan_weights": _zoo_nan_weights,
    "inf_weights": _zoo_inf_weights,
    "negative_weights": _zoo_negative_weights,
    "ids_past_n": _zoo_ids_past_n,
    "sacrificial_collision": _zoo_sacrificial_collision,
    "dup_self_loop_flood": _zoo_dup_self_loop_flood,
    "everything_at_once": _zoo_everything_at_once,
    "empty": _zoo_empty,
}

#: graphs where sanitize legitimately drops nothing
CLEAN_DIRT = {"dup_self_loop_flood", "empty"}


def _run_scan(stream, cfg):
    r = mwm_scan(stream, cfg)
    return np.asarray(r.assigned), np.asarray(r.mb)


def _run_ref(stream, cfg):
    w = jnp.where(stream.valid, stream.weight, 0.0)
    a, mb = substream_match_ref(stream.src, stream.dst, w, cfg.thresholds(), cfg.n)
    return np.asarray(a), np.asarray(mb).astype(bool)


def _run_waves_xla(stream, cfg):
    r = mwm_waves(stream, cfg)
    return np.asarray(r.assigned), np.asarray(r.mb)


def _run_pallas(schedule):
    def run(stream, cfg):
        r = substream_match(stream, cfg, interpret=True, schedule=schedule)
        return np.asarray(r.assigned), np.asarray(r.mb)

    return run


ENGINES = {
    "ref": _run_ref,
    "pallas_edges": _run_pallas("edges"),
    "pallas_waves": _run_pallas("waves"),
    "mega": _run_pallas("mega"),
    "waves_xla": _run_waves_xla,
}


def _manual_clean(stream, cfg):
    """Independently drop the bad edges with plain numpy comparisons."""
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    w = np.asarray(stream.weight)
    with np.errstate(invalid="ignore"):
        good = (
            (src >= 0) & (src < cfg.n) & (dst >= 0) & (dst < cfg.n)
            & np.isfinite(w) & (w >= 0)
        )
    return EdgeStream(
        src=stream.src, dst=stream.dst, weight=stream.weight,
        valid=jnp.asarray(np.asarray(stream.valid) & good),
    )


@pytest.mark.parametrize("engine", sorted(ENGINES))
@pytest.mark.parametrize("graph", sorted(DIRTY_ZOO))
def test_engines_bit_identical_after_sanitize(graph, engine):
    stream, cfg = DIRTY_ZOO[graph]()
    clean, report = validate_stream(stream, cfg.n, policy="sanitize")
    assert (report.num_dropped == 0) == (graph in CLEAN_DIRT)
    want_a, want_mb = _run_scan(clean, cfg)
    got_a, got_mb = ENGINES[engine](clean, cfg)
    assert (got_a == want_a).all(), f"{engine} diverges on sanitized {graph}"
    assert (got_mb == want_mb).all(), f"{engine} diverges on sanitized {graph}"
    # and sanitize agrees with an independent manual clean
    manual_a, manual_mb = _run_scan(_manual_clean(stream, cfg), cfg)
    assert (want_a == manual_a).all()
    assert (want_mb == manual_mb).all()


@pytest.mark.parametrize("graph", sorted(DIRTY_ZOO))
def test_strict_rejects_exactly_the_dirty_graphs(graph):
    stream, cfg = DIRTY_ZOO[graph]()
    if graph in CLEAN_DIRT:
        out, report = validate_stream(stream, cfg.n, policy="strict")
        assert out is stream and report.ok
    else:
        with pytest.raises(StreamValidationError):
            validate_stream(stream, cfg.n, policy="strict")


@pytest.mark.parametrize("graph", sorted(DIRTY_ZOO))
def test_postconditions_hold_on_sanitized_results(graph):
    stream, cfg = DIRTY_ZOO[graph]()
    clean, _ = validate_stream(stream, cfg.n, policy="sanitize")
    res = mwm_scan(clean, cfg)
    merged = merge_host(clean, res, cfg)
    exact = exact_mwm_weight(clean)
    check_matching(res, clean, cfg, merged=merged, exact_weight=exact)
    if exact > 0:
        assert matching_weight(clean, merged) > 0


def test_validation_report_counters_shape():
    stream, cfg = DIRTY_ZOO["everything_at_once"]()
    _, report = validate_stream(stream, cfg.n, policy="sanitize")
    counters = report.counters()
    assert counters["guard.dropped_edges"] == report.num_dropped > 0
    assert counters["guard.num_problems"] == len(report.problems) > 0
    for p in report.problems:
        assert counters[f"guard.fault.{p.kind}"] == p.count
    # stream_problems is pure and reports the same faults
    kinds = {
        p.kind
        for p in stream_problems(
            np.asarray(stream.src), np.asarray(stream.dst),
            np.asarray(stream.weight), np.asarray(stream.valid), cfg.n,
        )
    }
    assert kinds == {p.kind for p in report.problems}
    assert kinds == {"id_out_of_range", "nonfinite_weight", "negative_weight"}


# ---------------------------------------------------------------------------
# from_numpy cast guards (satellite: no silent int64 wrap / NaN propagation)
# ---------------------------------------------------------------------------


def test_from_numpy_strict_rejects_id_overflow():
    with pytest.raises(StreamValidationError, match="id_overflow"):
        EdgeStream.from_numpy(np.array([2**40], np.int64), [1], [1.0])


def test_from_numpy_strict_rejects_nonfinite_weights():
    for bad in (np.nan, np.inf, 1e40):  # 1e40 overflows the float32 cast
        with pytest.raises(StreamValidationError, match="nonfinite_weight"):
            EdgeStream.from_numpy([0], [1], np.array([bad]))


def test_from_numpy_sanitize_drops_unrepresentable():
    s = EdgeStream.from_numpy(
        np.array([2**40, 1, 2], np.int64), [1, 2, 3],
        np.array([1.0, np.nan, 3.0]), policy="sanitize",
    )
    assert np.asarray(s.valid).tolist() == [False, False, True]
    assert int(np.asarray(s.src)[2]) == 2
    assert float(np.asarray(s.weight)[2]) == 3.0


def test_from_numpy_off_is_legacy_wrap():
    s = EdgeStream.from_numpy(
        np.array([2**32], np.int64), [1], np.array([np.inf]), policy="off"
    )
    assert int(np.asarray(s.src)[0]) == 0  # wrapped, as before
    assert np.isinf(np.asarray(s.weight)[0])


def test_from_numpy_clean_int32_fast_path_unchanged():
    s = EdgeStream.from_numpy(
        np.array([0, 1], np.int32), np.array([1, 2], np.int32),
        np.array([1.5, 2.5], np.float32), n_pad=4,
    )
    assert np.asarray(s.valid).tolist() == [True, True, False, False]
    assert np.asarray(s.weight).tolist() == [1.5, 2.5, 0.0, 0.0]


def test_from_numpy_rejects_mismatched_lengths():
    with pytest.raises(ValueError, match="lengths differ"):
        EdgeStream.from_numpy([0, 1], [1], [1.0, 2.0])


def test_from_numpy_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        EdgeStream.from_numpy([0], [1], [1.0], policy="lenient")


# ---------------------------------------------------------------------------
# Degenerate streams: m == 0, all-dropped, n == 0 (satellite hardening)
# ---------------------------------------------------------------------------


def _assert_empty_result(res, stream, cfg):
    assert res.assigned.shape == (stream.num_edges,)
    assert (np.asarray(res.assigned) == -1).all()
    assert np.asarray(res.mb).shape == (cfg.n, cfg.L)
    assert not np.asarray(res.mb).any()
    merged = merge_host(stream, res, cfg)
    assert merged.shape == (0,) and merged.dtype == np.int64
    assert matching_weight(stream, merged) == 0.0
    assert not np.asarray(merge_device(stream, res, cfg)).any()
    check_matching(res, stream, cfg, merged=merged, exact_weight=0.0)


@pytest.mark.parametrize(
    "case", ["m0", "all_dropped", "n0", "n0_with_padding_edges"]
)
def test_degenerate_streams_well_formed_everywhere(case):
    if case == "m0":
        stream, cfg = _dirty(8, [], [], [])
    elif case == "all_dropped":
        stream, cfg = _dirty(8, [0, 1, 5], [1, 2, 5], [np.nan, -1.0, 2.0])
        stream, _ = validate_stream(stream, cfg.n, policy="sanitize")
        # the self-loop (5,5) survives sanitize but never matches
    elif case == "n0":
        stream, cfg = _dirty(0, [], [], [])
    else:
        stream = EdgeStream.from_numpy([], [], [], n_pad=6)
        cfg = SubstreamConfig(n=0, L=4)
    _assert_empty_result(mwm_scan(stream, cfg), stream, cfg)
    _assert_empty_result(mwm_waves(stream, cfg), stream, cfg)
    for schedule in ("edges", "waves", "mega"):
        res = substream_match(stream, cfg, interpret=True, schedule=schedule)
        _assert_empty_result(res, stream, cfg)


def test_n0_with_valid_edges_is_a_validation_problem():
    stream, _ = _dirty(8, [0, 1], [1, 2], [1.0, 2.0])
    problems = stream_problems(
        np.asarray(stream.src), np.asarray(stream.dst),
        np.asarray(stream.weight), np.asarray(stream.valid), 0,
    )
    assert [p.kind for p in problems] == ["empty_vertex_space"]
    with pytest.raises(StreamValidationError, match="empty_vertex_space"):
        validate_stream(stream, 0, policy="strict")
    clean, report = validate_stream(stream, 0, policy="sanitize")
    assert report.num_dropped == 2
    assert not np.asarray(clean.valid).any()
