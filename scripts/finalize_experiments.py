"""Inject the dry-run tables into EXPERIMENTS.md (run after the sweep)."""
import sys

sys.path.insert(0, "src")
from repro.launch.report import render_multipod_check, render_table  # noqa: E402

text = open("EXPERIMENTS.md.tmpl").read()
text = text.replace("{{ROOFLINE_TABLE}}", render_table("dryrun_results.json"))
text = text.replace(
    "{{BASELINE_TABLE}}", render_table("dryrun_baseline.json")
)
text = text.replace("{{MULTIPOD}}", render_multipod_check("dryrun_results.json"))
open("EXPERIMENTS.md", "w").write(text)
print("EXPERIMENTS.md written")
