"""Faithful substream-centric MWM — Listing 1 of the paper, in JAX.

Part 1 (stream processing): one pass over the edge stream; for every edge,
all ``L`` substreams are updated *in parallel* (the FPGA's bit-parallel
matching-bit word = our lane-vectorized [L] ops). Part 2 (post
processing): greedy merge in descending substream order (see
:mod:`repro.core.merge`).

This module is the CS-SEQ oracle: every other implementation (blocked /
Pallas / distributed rounds) is tested bit-identical against it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.types import EdgeStream, MatchingResult, SubstreamConfig, eligibility


@partial(jax.jit, static_argnames=("cfg",))
def mwm_scan(
    stream: EdgeStream, cfg: SubstreamConfig, mb0: jax.Array | None = None
) -> MatchingResult:
    """Listing 1, Part 1. Carries MB in a `lax.scan` over the stream.

    ``mb0`` (bool [n, L], default zeros) seeds the matching bits — the
    epoch executor's carried state; chunked runs stay bit-identical to
    one-shot because the greedy update is confluent in the carried MB.

    Per edge e=(u,v,w):
      te    = [w >= (1+eps)^i]_i                (eligibility, Stage 4)
      free  = ~MB[u] & ~MB[v]                   (Stage 5)
      add   = te & free
      MB[u]|= add ; MB[v]|= add                 (Stage 6)
      assigned = highest set bit of add, else -1 (Stage 7; `has_added`
                 collapses to "highest i" because the descending loop in
                 Listing 1 records the first i where the edge is added)
    """
    if cfg.n == 0:
        # scan traces its body even for zero iterations of work per edge,
        # and mb[u] on a zero-row block is an out-of-bounds gather — return
        # the well-formed empty result instead
        return MatchingResult(
            assigned=jnp.full((stream.num_edges,), -1, jnp.int32),
            mb=jnp.zeros((0, cfg.L), dtype=bool),
        )
    thr = cfg.thresholds()

    def step(mb, e):
        u, v, w, ok = e
        u = u.astype(jnp.int32)
        v = v.astype(jnp.int32)
        te = (w >= thr) & ok & (u != v)  # self-loops never match
        mbu = mb[u]
        mbv = mb[v]
        add = te & ~mbu & ~mbv
        mb = mb.at[u].set(mbu | add)
        mb = mb.at[v].set(mbv | add)
        idx = jnp.where(
            add, jax.lax.broadcasted_iota(jnp.int32, add.shape, 0), -1
        ).max()
        return mb, idx

    init = (
        jnp.zeros((cfg.n, cfg.L), dtype=bool)
        if mb0 is None
        else mb0.astype(bool)
    )
    mb, assigned = jax.lax.scan(
        step, init, (stream.src, stream.dst, stream.weight, stream.valid)
    )
    return MatchingResult(assigned=assigned, mb=mb)


@partial(jax.jit, static_argnames=("cfg",))
def substream_matchings(stream: EdgeStream, cfg: SubstreamConfig) -> jax.Array:
    """bool [m, L]: membership of each edge in each substream's matching M_i.

    Note M_i (defined by the matching *bits*) is a superset of the recorded
    list C_i — an edge can be matched in several substreams but recorded in
    one (Listing 1's ``has_added``). Some invariant tests need the full M_i.
    """
    if cfg.n == 0:
        return jnp.zeros((stream.num_edges, cfg.L), dtype=bool)
    thr = cfg.thresholds()

    def step(mb, e):
        u, v, w, ok = e
        u = u.astype(jnp.int32)
        v = v.astype(jnp.int32)
        te = (w >= thr) & ok & (u != v)
        add = te & ~mb[u] & ~mb[v]
        mb = mb.at[u].set(mb[u] | add)
        mb = mb.at[v].set(mb[v] | add)
        return mb, add

    mb0 = jnp.zeros((cfg.n, cfg.L), dtype=bool)
    _, added = jax.lax.scan(
        step, mb0, (stream.src, stream.dst, stream.weight, stream.valid)
    )
    return added


@partial(jax.jit, static_argnames=("cfg", "m"))
def _wave_scan(u, v, w, ok, slots, cfg: SubstreamConfig, m: int, mb0=None):
    """Scan over segments; each step is one vectorized [SEG, L] update.

    ``u/v/w/ok`` are the [num_segments, SEG] fill-packed slot arrays of
    :func:`repro.graph.waves.slot_arrays` — each row is a segment of one
    wave, so it is vertex-disjoint and the per-step work is proportional
    to ``SEG``, not to the largest wave. ``slots`` maps each slot back
    to its stream position (-1 = padding). Returns (assigned [m], mb).
    """
    thr = cfg.thresholds()

    def step(mb, wave):
        wu, wv, ww, wok = wave  # [W] each
        te = (ww[:, None] >= thr[None, :]) & wok[:, None] & (wu != wv)[:, None]
        mbu = mb[wu]  # [W, L]; wave edges are vertex-disjoint, so these
        mbv = mb[wv]  # reads cannot race the scatter below
        add = te & ~mbu & ~mbv
        # scatter-OR (max on bool): padding slots all alias row 0 with
        # add == False, so duplicate indices are no-ops by construction
        mb = mb.at[wu].max(add)
        mb = mb.at[wv].max(add)
        idx = jnp.where(
            add, jax.lax.broadcasted_iota(jnp.int32, add.shape, 1), -1
        ).max(axis=1)
        return mb, idx

    init = (
        jnp.zeros((cfg.n, cfg.L), dtype=bool)
        if mb0 is None
        else mb0.astype(bool)
    )
    mb, idx = jax.lax.scan(step, init, (u, v, w, ok))
    from repro.graph.waves import scatter_slot_assignments

    return scatter_slot_assignments(slots, idx, m), mb


def mwm_waves(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    schedule=None,
    max_width: int | None = None,
    telemetry=obs.DISABLED,
    mb0: jax.Array | None = None,
) -> MatchingResult:
    """Listing 1 Part 1 over conflict-free waves (XLA parity oracle).

    ``mb0`` (bool [n, L], default zeros) seeds the matching bits — the
    epoch executor's carried state.

    Decomposes the stream with :func:`repro.graph.waves.wave_schedule`
    (or reuses a precomputed ``schedule``) and processes one
    vertex-disjoint *segment* (a fill-packed chunk of one wave) per scan
    step — bit-identical to :func:`mwm_scan` in ``assigned`` and ``mb``
    because greedy matching is confluent over vertex-disjoint edges.
    ``#segments`` (≈ m / SEG on well-packed streams) scan steps of
    [SEG, L] vector work replace ``m`` scalar steps.

    Host-side scheduling makes this entry point non-jittable at the top
    level (the wave decomposition is data-dependent); the per-wave scan
    itself is jitted. ``telemetry`` records the same stage split as the
    Pallas engines (engine name ``waves_xla``).
    """
    if cfg.n == 0:
        return MatchingResult(
            assigned=jnp.full((stream.num_edges,), -1, jnp.int32),
            mb=jnp.zeros((0, cfg.L), dtype=bool),
        )
    from repro.graph import waves as _waves

    rec = obs.recorder(
        telemetry, "waves_xla", stream.num_edges, jax.default_backend()
    )
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    if schedule is None:
        schedule = _waves.resolve_schedule(
            src, dst, valid, schedule=None, max_width=max_width,
            telemetry=telemetry,
        )
        rec.add_stage("schedule", schedule.schedule_seconds)
        rec.add_stage("pack", schedule.pack_seconds)
    else:
        with rec.stage("schedule"):  # precomputed: validation cost only
            schedule = _waves.resolve_schedule(
                src, dst, valid, schedule=schedule, max_width=max_width,
                telemetry=telemetry,
            )
    with rec.stage("layout"):
        u, v, w, ok = _waves.slot_arrays(
            schedule, src, dst, np.asarray(stream.weight), valid
        )
    if telemetry.enabled:
        rec.put_many(_waves.schedule_counters(schedule))
        rec.put("stream.num_edges", stream.num_edges)
    key = (
        "waves_xla", schedule.num_segments, schedule.width, cfg.n, cfg.L,
        cfg.eps, stream.num_edges, mb0 is not None,
    )
    with rec.device_stage(key):
        assigned, mb = _wave_scan(
            jnp.asarray(u),
            jnp.asarray(v),
            jnp.asarray(w),
            jnp.asarray(ok),
            jnp.asarray(schedule.slots),
            cfg,
            stream.num_edges,
            mb0=None if mb0 is None else jnp.asarray(mb0),
        )
        rec.block((assigned, mb))
    rec.finish()
    return MatchingResult(assigned=assigned, mb=mb)
