"""Faithful substream-centric MWM — Listing 1 of the paper, in JAX.

Part 1 (stream processing): one pass over the edge stream; for every edge,
all ``L`` substreams are updated *in parallel* (the FPGA's bit-parallel
matching-bit word = our lane-vectorized [L] ops). Part 2 (post
processing): greedy merge in descending substream order (see
:mod:`repro.core.merge`).

This module is the CS-SEQ oracle: every other implementation (blocked /
Pallas / distributed rounds) is tested bit-identical against it.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.types import EdgeStream, MatchingResult, SubstreamConfig, eligibility


@partial(jax.jit, static_argnames=("cfg",))
def mwm_scan(stream: EdgeStream, cfg: SubstreamConfig) -> MatchingResult:
    """Listing 1, Part 1. Carries MB in a `lax.scan` over the stream.

    Per edge e=(u,v,w):
      te    = [w >= (1+eps)^i]_i                (eligibility, Stage 4)
      free  = ~MB[u] & ~MB[v]                   (Stage 5)
      add   = te & free
      MB[u]|= add ; MB[v]|= add                 (Stage 6)
      assigned = highest set bit of add, else -1 (Stage 7; `has_added`
                 collapses to "highest i" because the descending loop in
                 Listing 1 records the first i where the edge is added)
    """
    thr = cfg.thresholds()

    def step(mb, e):
        u, v, w, ok = e
        u = u.astype(jnp.int32)
        v = v.astype(jnp.int32)
        te = (w >= thr) & ok & (u != v)  # self-loops never match
        mbu = mb[u]
        mbv = mb[v]
        add = te & ~mbu & ~mbv
        mb = mb.at[u].set(mbu | add)
        mb = mb.at[v].set(mbv | add)
        idx = jnp.where(
            add, jax.lax.broadcasted_iota(jnp.int32, add.shape, 0), -1
        ).max()
        return mb, idx

    mb0 = jnp.zeros((cfg.n, cfg.L), dtype=bool)
    mb, assigned = jax.lax.scan(
        step, mb0, (stream.src, stream.dst, stream.weight, stream.valid)
    )
    return MatchingResult(assigned=assigned, mb=mb)


@partial(jax.jit, static_argnames=("cfg",))
def substream_matchings(stream: EdgeStream, cfg: SubstreamConfig) -> jax.Array:
    """bool [m, L]: membership of each edge in each substream's matching M_i.

    Note M_i (defined by the matching *bits*) is a superset of the recorded
    list C_i — an edge can be matched in several substreams but recorded in
    one (Listing 1's ``has_added``). Some invariant tests need the full M_i.
    """
    thr = cfg.thresholds()

    def step(mb, e):
        u, v, w, ok = e
        u = u.astype(jnp.int32)
        v = v.astype(jnp.int32)
        te = (w >= thr) & ok & (u != v)
        add = te & ~mb[u] & ~mb[v]
        mb = mb.at[u].set(mb[u] | add)
        mb = mb.at[v].set(mb[v] | add)
        return mb, add

    mb0 = jnp.zeros((cfg.n, cfg.L), dtype=bool)
    _, added = jax.lax.scan(
        step, mb0, (stream.src, stream.dst, stream.weight, stream.valid)
    )
    return added
