"""Bit-packing of the matching-bit block (§4.3's BRAM word, TPU edition).

The FPGA stores each vertex's matching state as ONE L-bit word in BRAM.
The unpacked TPU layout spends an int8 lane per substream bit — 8× the
storage the paper's design needs. This module defines the packed
*bit-plane* layout used everywhere downstream:

    mb_packed[v, k] : uint8, bit j of word k  ==  substream 8*k + j of v

i.e. substream index i lives at byte ``i // 8``, bit ``i % 8`` (LSB
first). ``L`` need not divide 8; the high bits of the last byte are
always zero. Pack/unpack are exact inverses on the first L bits and are
cheap enough to run lazily on host access (see
:class:`repro.core.types.MatchingResult`).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BITS = 8  # bits per packed word (uint8 lanes)


def packed_width(L: int) -> int:
    """Number of uint8 words holding L substream bits: ceil(L / 8)."""
    return -(-L // BITS)


def pack_bits(mb: jax.Array) -> jax.Array:
    """bool/int [..., L] -> uint8 [..., ceil(L/8)], LSB-first bit planes."""
    L = mb.shape[-1]
    W = packed_width(L)
    x = mb.astype(jnp.uint8)
    pad = W * BITS - L
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros(mb.shape[:-1] + (pad,), jnp.uint8)], axis=-1
        )
    x = x.reshape(mb.shape[:-1] + (W, BITS))
    weights = (1 << jnp.arange(BITS, dtype=jnp.int32)).astype(jnp.int32)
    return (x.astype(jnp.int32) * weights).sum(axis=-1).astype(jnp.uint8)


def unpack_bits(packed: jax.Array, L: int) -> jax.Array:
    """uint8 [..., W] -> bool [..., L]; inverse of :func:`pack_bits`."""
    W = packed.shape[-1]
    if W < packed_width(L):
        raise ValueError(f"{W} words cannot hold {L} bits")
    shifts = jnp.arange(BITS, dtype=jnp.uint8)
    bits = (packed[..., :, None] >> shifts) & jnp.uint8(1)
    return bits.reshape(packed.shape[:-1] + (W * BITS,))[..., :L].astype(bool)
