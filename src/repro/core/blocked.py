"""Blocking + lexicographic ordering (Listing 2 / SC-OPT analogue).

§4.2: merge K adjacent adjacency-matrix rows into an *epoch* and order the
epoch's edges lexicographically by ``(epoch(u), v, u)`` (weight ignored).
On the FPGA this lets u-bits live in BRAM and v-bit DRAM writes batch per
epoch; on TPU the same order maximizes temporal reuse of the VMEM-resident
matching-bit rows inside the Pallas kernel and turns the v-bit traffic into
near-sequential VMEM row touches.

Greedy guarantee note: reordering changes *which* maximal matching each
substream yields, but any maximal matching preserves the (4+eps) bound —
same argument the paper uses for SC-OPT vs CS-SEQ.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import EdgeStream, MatchingResult, SubstreamConfig
from repro.core import matching as _matching


def lexicographic_order(stream: EdgeStream, K: int) -> jax.Array:
    """Permutation sorting edges by (epoch(u), v, u); §4.2.3, 0-indexed.

    Invalid (padding) edges sort to the end.
    """
    u = stream.src.astype(jnp.int32)
    v = stream.dst.astype(jnp.int32)
    epoch = jnp.where(stream.valid, u // K, jnp.iinfo(jnp.int32).max)
    m = u.shape[0]
    pos = jnp.arange(m, dtype=jnp.int32)
    # multi-key sort: (epoch, v, u), stream position breaks remaining ties
    _, _, _, order = jax.lax.sort((epoch, v, u, pos), num_keys=3, is_stable=True)
    return order


def permute_stream(stream: EdgeStream, order: jax.Array) -> EdgeStream:
    return EdgeStream(
        src=stream.src[order],
        dst=stream.dst[order],
        weight=stream.weight[order],
        valid=stream.valid[order],
    )


def mwm_blocked(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    K: int = 32,
    backend: str = "scan",
    **kernel_kwargs,
) -> MatchingResult:
    """Listing 2: lexicographic blocked processing.

    backend='scan'   : XLA scan over the blocked order (reference).
    backend='pallas' : the substream_match Pallas kernel (SC-OPT path).

    ``assigned`` is returned in the *original* stream order.
    """
    order = lexicographic_order(stream, K)
    blocked = permute_stream(stream, order)
    if backend == "scan":
        res = _matching.mwm_scan(blocked, cfg)
    elif backend == "pallas":
        from repro.kernels.substream_match import ops as _ops

        res = _ops.substream_match(blocked, cfg, **kernel_kwargs)
    else:
        raise ValueError(f"unknown backend {backend!r}")
    m = stream.num_edges
    assigned = jnp.zeros((m,), jnp.int32).at[order].set(res.assigned)
    # keep whichever bit storage the backend produced (packed stays packed)
    return res.with_assigned(assigned)
