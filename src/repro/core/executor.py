"""Deadline / retry / straggler guard around device execution.

PR 8's guard layer covers *data* faults (bad streams in, bad matchings
out) and plan faults (the fallback cascade). This module covers
*execution* faults on a long chunked run: a flaky interconnect, a
preempted device, a hung collective. Policy:

* **transient** faults (``err.transient`` truthy, ``TimeoutError`` /
  ``ConnectionError`` / :class:`DeadlineExceededError`) are retried on
  the same engine with exponential backoff, up to ``retries`` times;
* **permanent** faults propagate immediately — the engine call itself
  is expected to run with ``on_plan_failure="fallback"``, so anything
  that escapes it has already exhausted the degradation ladder, and
  validation/invariant errors mean retrying would just recompute the
  same wrong answer;
* every epoch's wall time feeds the
  :class:`repro.distributed.straggler.StragglerMonitor` EWMA — an
  epoch slower than ``threshold`` x the running mean emits a
  ``guard.straggler`` telemetry event (the single-host analogue of
  GraVF-M's slow-node detection; on a cluster the event would feed the
  remesh planner).

The deadline is checked *post hoc*: a dispatched JAX computation
cannot be preempted from Python, so a blown deadline classifies the
epoch as transiently failed (and retries it) rather than interrupting
it. That is the honest single-process semantics — the point is to
bound how long a hung epoch can stall the run before the guard reacts.

Injection seams for tests: ``clock`` (monotonic seconds) and ``sleep``
— faultline's ``FakeClock`` drives both, so backoff schedules are
asserted deterministically without real waiting.
"""
from __future__ import annotations

import time
from typing import Callable, Optional

from repro import obs


class DeadlineExceededError(RuntimeError):
    """An epoch ran past the guard's per-attempt deadline.

    Classified transient: the typical cause is a hung or contended
    device, and the retry re-dispatches the same work.
    """

    transient = True

    def __init__(self, seconds: float, deadline: float):
        self.seconds = seconds
        self.deadline = deadline
        super().__init__(
            f"epoch took {seconds:.3f}s, deadline {deadline:.3f}s"
        )


class RetriesExhaustedError(RuntimeError):
    """Transient failures persisted past the retry budget.

    ``attempts`` is the ordered list of exceptions, one per attempt —
    mirrors :class:`repro.kernels.substream_match.ops
    .FallbackExhaustedError` so logs show the whole story.
    """

    def __init__(self, attempts):
        self.attempts = tuple(attempts)
        lines = "; ".join(
            f"attempt {i}: {type(e).__name__}: {e}"
            for i, e in enumerate(self.attempts)
        )
        super().__init__(f"retries exhausted ({lines})")


def is_transient(err: BaseException) -> bool:
    """Fault classification: retry-worthy or not.

    An explicit ``transient`` attribute wins either way (faultline's
    ``TransientFlake`` sets it true; a subclass could pin it false);
    otherwise OS-level timeout/connection errors default to transient
    and everything else to permanent.
    """
    flag = getattr(err, "transient", None)
    if flag is not None:
        return bool(flag)
    return isinstance(err, (TimeoutError, ConnectionError))


class ExecutionGuard:
    """Bounded-retry executor for one epoch-shaped unit of work.

    ``deadline`` is per attempt in seconds (``None`` = unbounded);
    ``retries`` is the number of *re*-tries after the first attempt;
    backoff before retry ``k`` (1-based) is ``backoff * backoff_factor
    ** (k - 1)`` seconds. ``monitor`` is an optional
    :class:`repro.distributed.straggler.StragglerMonitor` fed with each
    successful attempt's duration.

    ``retry_log`` keeps ``(label, exception, slept_seconds)`` per retry
    for tests and post-mortems; ``guard.retry`` counts retries on the
    telemetry session and a ``guard.retry`` event names the cause.
    """

    def __init__(
        self,
        deadline: Optional[float] = None,
        retries: int = 2,
        backoff: float = 0.05,
        backoff_factor: float = 2.0,
        monitor=None,
        telemetry=obs.DISABLED,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
    ):
        if retries < 0:
            raise ValueError(f"retries must be >= 0, got {retries}")
        self.deadline = deadline
        self.retries = retries
        self.backoff = backoff
        self.backoff_factor = backoff_factor
        self.monitor = monitor
        self.telemetry = telemetry
        self.clock = clock
        self.sleep = sleep
        self.retry_log: list[tuple[str, BaseException, float]] = []

    def run(self, fn: Callable[[], object], label: str = "epoch"):
        """Run ``fn`` under the deadline/retry policy; returns its value.

        Raises :class:`RetriesExhaustedError` when transient failures
        outlast the budget, or the original exception unchanged when it
        is permanent. ``BaseException`` (incl. faultline's
        ``SimulatedCrash``) is never absorbed — a crash is a crash.
        """
        failures: list[BaseException] = []
        for attempt in range(self.retries + 1):
            start = self.clock()
            try:
                out = fn()
                elapsed = self.clock() - start
                if self.deadline is not None and elapsed > self.deadline:
                    raise DeadlineExceededError(elapsed, self.deadline)
            except Exception as err:  # noqa: BLE001 — classified below
                if not is_transient(err):
                    raise
                failures.append(err)
                if attempt == self.retries:
                    raise RetriesExhaustedError(failures) from err
                delay = self.backoff * self.backoff_factor**attempt
                self.telemetry.count("guard.retry")
                self.telemetry.event(
                    "guard.retry",
                    label=label,
                    attempt=attempt,
                    delay_seconds=delay,
                    reason=f"{type(err).__name__}: {err}"[:500],
                )
                self.retry_log.append((label, err, delay))
                self.sleep(delay)
                continue
            self._observe(label, elapsed)
            return out
        raise AssertionError("unreachable")  # pragma: no cover

    def _observe(self, label: str, elapsed: float) -> None:
        if self.monitor is None:
            return
        event = self.monitor.observe(elapsed)
        if event is not None:
            self.telemetry.count("guard.straggler")
            self.telemetry.event(
                "guard.straggler",
                label=label,
                step=event.step,
                seconds=event.step_time,
                ewma=event.ewma,
                ratio=event.ratio,
            )
