"""G-SEQ — Ghaffari / Paz–Schwartzman (2+eps) semi-streaming MWM baseline.

The paper benchmarks against this algorithm (§5.1.1, [62]); we implement it
so every paper figure has its comparison target. Local-ratio scheme:

  for each streamed edge e=(u,v,w):
      if w >= (1+eps') * (phi[u] + phi[v]):
          g = w - phi[u] - phi[v]
          push e (stack);  phi[u] += g;  phi[v] += g
  unwind the stack, greedily keeping edges whose endpoints are free.

Space O(n log n) bits + stack; one pass. Approximation (2 + eps).
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.types import EdgeStream


@partial(jax.jit, static_argnames=("n", "eps"))
def _gseq_pass(stream: EdgeStream, n: int, eps: float):
    def step(phi, e):
        u, v, w, ok = e
        u = u.astype(jnp.int32)
        v = v.astype(jnp.int32)
        keep = ok & (w >= (1.0 + eps) * (phi[u] + phi[v])) & (u != v)
        g = jnp.where(keep, w - phi[u] - phi[v], 0.0)
        phi = phi.at[u].add(g)
        phi = phi.at[v].add(g)
        return phi, keep

    phi0 = jnp.zeros((n,), jnp.float32)
    _, kept = jax.lax.scan(
        step, phi0, (stream.src, stream.dst, stream.weight, stream.valid)
    )
    return kept


def gseq(stream: EdgeStream, n: int, eps: float = 0.1) -> np.ndarray:
    """Returns stream indices of the (2+eps)-approximate matching."""
    kept = np.asarray(_gseq_pass(stream, n, eps))
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    used = np.zeros(n, bool)
    out = []
    for e in np.nonzero(kept)[0][::-1]:  # unwind stack (reverse order)
        u, v = src[e], dst[e]
        if not used[u] and not used[v]:
            used[u] = True
            used[v] = True
            out.append(e)
    return np.asarray(sorted(out), dtype=np.int64)
