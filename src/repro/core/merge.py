"""Part 2 (post processing) — greedy merge of the L matchings into the MWM.

The paper runs this on the CPU (<1 % of time, little parallelism). We keep
the faithful host version (numpy) and additionally offer a device version
built on the same greedy-priority machinery as Part 1: merging in
"descending i, then stream order" is itself a greedy maximal matching under
the total priority order ``(L-1-i, position)``, so `mwm_scan` can run it.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.core.types import EdgeStream, MatchingResult, SubstreamConfig
from repro.core import matching as _matching


def merge_host(
    stream: EdgeStream, result: MatchingResult, cfg: SubstreamConfig,
    telemetry=obs.DISABLED,
) -> np.ndarray:
    """Faithful Listing 1 Part 2. Returns indices (into the stream) of T.

    Consumes only ``result.assigned`` — Part 2 never reads the matching
    bits, so packed-storage results merge without ever unpacking ``mb``.

    The merge order "descending substream i, then stream position" is
    realized with ONE stable argsort over the recorded edges (key
    ``L-1-i``; stability supplies the stream-position minor key), then a
    single greedy pass over those edges only — O(R log R + R) for R
    recorded edges instead of the old O(L·m) scan of the whole stream
    per substream. The greedy pass itself is the dependency chain and
    stays a loop, exactly like the paper's sequential post-processor.

    ``telemetry`` records one ``merge.host`` span plus the recorded /
    matched edge counters.
    """
    with telemetry.span("merge.host"):
        src = np.asarray(stream.src)
        dst = np.asarray(stream.dst)
        assigned = np.asarray(result.assigned)
        recorded = np.nonzero(assigned >= 0)[0]
        if recorded.size == 0:
            # empty / all-dropped streams: a well-formed empty T, skipping
            # the n-sized tbits allocation (n may be 0 here)
            if telemetry.enabled:
                telemetry.counters.add("merge.host.calls")
                telemetry.counters.put("merge.recorded_edges", 0)
                telemetry.counters.put("merge.matched_edges", 0)
            return np.zeros(0, dtype=np.int64)
        # descending i, stream order within i: stable sort on the major key
        # alone (``recorded`` is already ascending in stream position)
        order = recorded[np.argsort(cfg.L - 1 - assigned[recorded], kind="stable")]
        tbits = np.zeros(cfg.n, dtype=bool)
        out = []
        for e in order.tolist():
            u, v = src[e], dst[e]
            if not tbits[u] and not tbits[v]:
                tbits[u] = True
                tbits[v] = True
                out.append(e)
        merged = np.sort(np.asarray(out, dtype=np.int64))
    if telemetry.enabled:
        telemetry.counters.add("merge.host.calls")
        telemetry.counters.put("merge.recorded_edges", int(recorded.size))
        telemetry.counters.put("merge.matched_edges", int(merged.size))
    return merged


def merge_device(
    stream: EdgeStream, result: MatchingResult, cfg: SubstreamConfig,
    telemetry=obs.DISABLED,
) -> jax.Array:
    """Device-side merge: bool [m] membership mask of T (beyond-paper).

    Re-orders the recorded edges by (descending i, stream position) and runs
    the same one-substream greedy scan. Bit-identical to `merge_host`.
    Like `merge_host`, reads only ``result.assigned`` (packed-safe).
    ``telemetry`` records one ``merge.device`` span.
    """
    with telemetry.span("merge.device"):
        m = stream.num_edges
        assigned = result.assigned
        recorded = assigned >= 0
        # priority: (L-1-i) major, stream position minor — a *stable* argsort on
        # the major key alone keeps stream order inside each substream list.
        major = jnp.where(recorded, cfg.L - 1 - assigned, cfg.L)
        order = jnp.argsort(major, stable=True)
        perm = EdgeStream(
            src=stream.src[order],
            dst=stream.dst[order],
            weight=jnp.ones((m,), jnp.float32),  # single substream, all eligible
            valid=recorded[order],
        )
        one = SubstreamConfig(n=cfg.n, L=1, eps=cfg.eps)
        res = _matching.mwm_scan(perm, one)
        in_t_perm = res.assigned >= 0
        # scatter back to stream order
        mask = jnp.zeros((m,), bool).at[order].set(in_t_perm)
        if telemetry.enabled:
            jax.block_until_ready(mask)
    if telemetry.enabled:
        telemetry.counters.add("merge.device.calls")
    return mask


def matching_weight(stream: EdgeStream, edge_idx: np.ndarray) -> float:
    # the int64 cast keeps empty python lists indexable (np.asarray([])
    # is float64, which cannot index)
    idx = np.asarray(edge_idx, dtype=np.int64)
    if idx.size == 0:
        return 0.0
    w = np.asarray(stream.weight)
    return float(w[idx].sum())
