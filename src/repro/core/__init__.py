"""Substream-centric maximum weighted matching — the paper's contribution.

Public API:
  EdgeStream, SubstreamConfig, MatchingResult  — data types
  mwm_scan              — faithful Listing 1 Part 1 (CS-SEQ oracle)
  substream_matchings   — full [m, L] per-substream membership
  mwm_blocked           — Listing 2 blocked/lexicographic (SC-OPT path)
  mwm_rounds(_sharded)  — deterministic parallel rounds (beyond-paper)
  merge_host/merge_device — Part 2 greedy merge
  gseq                  — Ghaffari (2+eps) baseline (G-SEQ)
  exact_mwm_weight      — networkx oracle (tests/benchmarks)
  mwm_pipeline          — end-to-end: Part 1 + Part 2 → matching + weight
  validate_stream / check_matching — input guard + result invariants
                          (strict / sanitize / off policies, repro.core.guard)
  MatchState            — resumable per-stream-position state (repro.core.state)
  ExecutionGuard        — deadline/retry/straggler guard (repro.core.executor)
"""
from __future__ import annotations

import numpy as np

from repro.core.bitpack import pack_bits, packed_width, unpack_bits
from repro.core.types import (
    EdgeStream,
    MatchingResult,
    SubstreamConfig,
    eligibility,
)
from repro.core.guard import (
    MatchingInvariantError,
    StreamValidationError,
    ValidationReport,
    check_matching,
    matching_problems,
    stream_problems,
    validate_stream,
)
from repro.core.executor import (
    DeadlineExceededError,
    ExecutionGuard,
    RetriesExhaustedError,
    is_transient,
)
from repro.core.matching import mwm_scan, mwm_waves, substream_matchings
from repro.core.state import MatchState, fingerprint_for
from repro.core.blocked import mwm_blocked, lexicographic_order, permute_stream
from repro.core.rounds import mwm_rounds, mwm_rounds_sharded
from repro.core.merge import merge_host, merge_device, matching_weight
from repro.core.gseq import gseq
from repro.core.exact import exact_mwm_weight


def mwm_pipeline(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    part1: str = "scan",
    K: int = 32,
    **kw,
):
    """End-to-end (4+eps)-approx MWM. Returns (edge_indices, weight).

    part1 in {'scan', 'waves', 'blocked', 'pallas', 'rounds'}.
    """
    if part1 == "scan":
        res = mwm_scan(stream, cfg)
    elif part1 == "waves":
        res = mwm_waves(stream, cfg, **kw)
    elif part1 == "blocked":
        res = mwm_blocked(stream, cfg, K=K, backend="scan")
    elif part1 == "pallas":
        res = mwm_blocked(stream, cfg, K=K, backend="pallas", **kw)
    elif part1 == "rounds":
        res = mwm_rounds(stream, cfg)
    else:
        raise ValueError(part1)
    idx = merge_host(stream, res, cfg)
    return idx, matching_weight(stream, idx)


__all__ = [
    "EdgeStream",
    "MatchingResult",
    "SubstreamConfig",
    "eligibility",
    "pack_bits",
    "packed_width",
    "unpack_bits",
    "validate_stream",
    "stream_problems",
    "check_matching",
    "matching_problems",
    "StreamValidationError",
    "MatchingInvariantError",
    "ValidationReport",
    "mwm_scan",
    "mwm_waves",
    "substream_matchings",
    "mwm_blocked",
    "lexicographic_order",
    "permute_stream",
    "mwm_rounds",
    "mwm_rounds_sharded",
    "merge_host",
    "merge_device",
    "matching_weight",
    "gseq",
    "exact_mwm_weight",
    "mwm_pipeline",
    "MatchState",
    "fingerprint_for",
    "ExecutionGuard",
    "DeadlineExceededError",
    "RetriesExhaustedError",
    "is_transient",
]
