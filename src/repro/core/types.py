"""Core data types for the substream-centric matching framework.

An edge stream is a struct-of-arrays: ``src[i], dst[i], weight[i]`` in
*stream order* (the order the paper's FPGA would receive them). All
algorithms in :mod:`repro.core` treat the stream order as the greedy
priority order, exactly like Listing 1 of the paper.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeStream:
    """A weighted edge stream. ``src``/``dst`` are int32 [m], ``weight`` f32 [m].

    ``valid`` masks padding edges (False entries are ignored by every
    matcher); padding lets us keep shapes static under jit/shard_map.
    """

    src: jax.Array
    dst: jax.Array
    weight: jax.Array
    valid: jax.Array  # bool [m]

    @property
    def num_edges(self) -> int:
        return self.src.shape[0]

    @staticmethod
    def from_numpy(src, dst, weight, n_pad: Optional[int] = None) -> "EdgeStream":
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        weight = np.asarray(weight, np.float32)
        m = src.shape[0]
        m_pad = m if n_pad is None else n_pad
        if m_pad < m:
            raise ValueError(f"pad {m_pad} < m {m}")
        pad = m_pad - m
        valid = np.concatenate([np.ones(m, bool), np.zeros(pad, bool)])
        z = np.zeros(pad, np.int32)
        return EdgeStream(
            src=jnp.asarray(np.concatenate([src, z])),
            dst=jnp.asarray(np.concatenate([dst, z])),
            weight=jnp.asarray(np.concatenate([weight, np.zeros(pad, np.float32)])),
            valid=jnp.asarray(valid),
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SubstreamConfig:
    """Parameters of the Crouch–Stubbs reduction.

    ``L`` substreams; substream ``i`` admits edges with
    ``w >= (1 + eps)**i``. The paper selects ``eps`` per L
    (Fig. 11 caption); we expose both knobs.
    """

    n: int = dataclasses.field(metadata=dict(static=True))
    L: int = dataclasses.field(metadata=dict(static=True))
    eps: float = dataclasses.field(default=0.1, metadata=dict(static=True))

    def thresholds(self) -> jax.Array:
        """[L] array of substream admission thresholds (1+eps)^i."""
        i = jnp.arange(self.L, dtype=jnp.float32)
        return (1.0 + self.eps) ** i

    @property
    def w_max(self) -> float:
        return float((1.0 + self.eps) ** self.L)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class MatchingResult:
    """Output of Part 1 (stream processing).

    ``assigned`` int32 [m]: the substream index whose list ``C[i]`` records
    the edge (the *highest* eligible substream where both endpoints were
    free), or -1 if the edge entered no list. ``mb`` bool [n, L]: final
    matching bits.
    """

    assigned: jax.Array
    mb: jax.Array


def eligibility(weights: jax.Array, thresholds: jax.Array) -> jax.Array:
    """te[e, i] = w(e) >= (1+eps)^i — the L-bit eligibility vector (§4.4 Stage 4)."""
    return weights[:, None] >= thresholds[None, :]
