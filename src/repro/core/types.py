"""Core data types for the substream-centric matching framework.

An edge stream is a struct-of-arrays: ``src[i], dst[i], weight[i]`` in
*stream order* (the order the paper's FPGA would receive them). All
algorithms in :mod:`repro.core` treat the stream order as the greedy
priority order, exactly like Listing 1 of the paper.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack

_I32 = np.iinfo(np.int32)


def _int32_cast_faults(a: np.ndarray) -> np.ndarray:
    """bool mask: True where ``a.astype(np.int32)`` would change the value."""
    if a.dtype == np.int32 or a.dtype == bool:
        return np.zeros(a.shape, bool)
    if np.issubdtype(a.dtype, np.integer):
        return (a < _I32.min) | (a > _I32.max)
    if np.issubdtype(a.dtype, np.floating):
        with np.errstate(invalid="ignore"):
            bad = ~np.isfinite(a) | (a < _I32.min) | (a > _I32.max)
            frac = np.zeros(a.shape, bool)
            ok = ~bad
            frac[ok] = a[ok] != np.trunc(a[ok])
        return bad | frac
    try:  # exotic dtypes (object arrays of python ints): round-trip via int64
        a64 = a.astype(np.int64)
    except (TypeError, ValueError, OverflowError):
        return np.ones(a.shape, bool)
    return (a64 < _I32.min) | (a64 > _I32.max)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeStream:
    """A weighted edge stream. ``src``/``dst`` are int32 [m], ``weight`` f32 [m].

    ``valid`` masks padding edges (False entries are ignored by every
    matcher); padding lets us keep shapes static under jit/shard_map.
    """

    src: jax.Array
    dst: jax.Array
    weight: jax.Array
    valid: jax.Array  # bool [m]

    @property
    def num_edges(self) -> int:
        return self.src.shape[0]

    @staticmethod
    def from_numpy(
        src, dst, weight, n_pad: Optional[int] = None, policy: str = "strict"
    ) -> "EdgeStream":
        """Build a stream from host arrays, guarding the narrowing casts.

        The int32/float32 casts can silently destroy data: an int64
        vertex id wraps modulo 2^32, a float64 weight overflows to Inf.
        ``policy`` controls what happens to entries the casts cannot
        represent (ids outside int32, weights non-finite after the
        float32 cast):

        * ``"strict"`` (default) — raise a structured
          :class:`repro.core.guard.StreamValidationError` naming the
          offending positions;
        * ``"sanitize"`` — drop those edges (``valid=False``, slots
          zeroed like padding);
        * ``"off"`` — the legacy wrap/NaN-propagate cast, for callers
          that have already validated.

        Range checks against ``n`` (ids in ``[0, n)``, negative/NaN
        weights) are :func:`repro.core.guard.validate_stream`'s job —
        this only guards representability of the casts themselves.
        """
        if policy not in ("strict", "sanitize", "off"):
            raise ValueError(
                f"unknown policy {policy!r}; use 'strict', 'sanitize' or 'off'"
            )
        src_in = np.asarray(src)
        dst_in = np.asarray(dst)
        w_in = np.asarray(weight)
        m = src_in.shape[0]
        if dst_in.shape[0] != m or w_in.shape[0] != m:
            raise ValueError(
                f"src/dst/weight lengths differ: "
                f"{m}/{dst_in.shape[0]}/{w_in.shape[0]}"
            )
        drop = np.zeros(m, bool)
        if policy != "off" and m:
            from repro.core import guard  # deferred: guard imports this module

            bad_id = _int32_cast_faults(src_in) | _int32_cast_faults(dst_in)
            with np.errstate(invalid="ignore", over="ignore"):
                bad_w = ~np.isfinite(w_in.astype(np.float32))
            problems = [
                guard._problem(kind, mask, detail=detail)
                for kind, mask, detail in (
                    ("id_overflow", bad_id, "vertex id not representable as int32"),
                    ("nonfinite_weight", bad_w, "weight non-finite after the float32 cast"),
                )
                if mask.any()
            ]
            if problems:
                if policy == "strict":
                    raise guard.StreamValidationError(problems)
                drop = bad_id | bad_w
        with np.errstate(invalid="ignore", over="ignore"):
            src_np = np.where(drop, 0, src_in).astype(np.int32)
            dst_np = np.where(drop, 0, dst_in).astype(np.int32)
            w_np = np.where(drop, 0.0, w_in).astype(np.float32)
        m_pad = m if n_pad is None else n_pad
        if m_pad < m:
            raise ValueError(f"pad {m_pad} < m {m}")
        pad = m_pad - m
        valid = np.concatenate([~drop, np.zeros(pad, bool)])
        z = np.zeros(pad, np.int32)
        return EdgeStream(
            src=jnp.asarray(np.concatenate([src_np, z])),
            dst=jnp.asarray(np.concatenate([dst_np, z])),
            weight=jnp.asarray(np.concatenate([w_np, np.zeros(pad, np.float32)])),
            valid=jnp.asarray(valid),
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SubstreamConfig:
    """Parameters of the Crouch–Stubbs reduction.

    ``L`` substreams; substream ``i`` admits edges with
    ``w >= (1 + eps)**i``. The paper selects ``eps`` per L
    (Fig. 11 caption); we expose both knobs.
    """

    n: int = dataclasses.field(metadata=dict(static=True))
    L: int = dataclasses.field(metadata=dict(static=True))
    eps: float = dataclasses.field(default=0.1, metadata=dict(static=True))
    # Matching-bit storage layout: "packed" (uint8 bit planes, the §4.3
    # BRAM-word analogue — 8x the VMEM capacity) or "unpacked" (one int8
    # per bit; the legacy fallback). Consumed by kernels/substream_match.
    mb_layout: str = dataclasses.field(default="packed", metadata=dict(static=True))

    def thresholds(self) -> jax.Array:
        """[L] array of substream admission thresholds (1+eps)^i."""
        i = jnp.arange(self.L, dtype=jnp.float32)
        return (1.0 + self.eps) ** i

    @property
    def w_max(self) -> float:
        return float((1.0 + self.eps) ** self.L)


class MatchingResult:
    """Output of Part 1 (stream processing).

    ``assigned`` int32 [m]: the substream index whose list ``C[i]`` records
    the edge (the *highest* eligible substream where both endpoints were
    free), or -1 if the edge entered no list.

    The matching bits are held in ONE of two storages:

    * ``mb`` bool [n, L] — the dense view every pre-existing caller reads;
    * ``mb_packed`` uint8 [n, ceil(L/8)] — the bit-plane layout of
      :mod:`repro.core.bitpack` (the paper's §4.3 BRAM word).

    ``.mb`` is always readable: when only the packed storage is present it
    is unpacked lazily on access (outside any jit), so packed producers
    don't break dense consumers. ``.packed()`` is the mirror-image accessor.
    ``L`` (static) records the logical substream count; it is required to
    trim the last byte's padding bits when unpacking.
    """

    __slots__ = ("assigned", "_mb", "_mb_packed", "_L")

    def __init__(self, assigned, mb=None, mb_packed=None, L=None):
        if mb is None and mb_packed is None:
            raise ValueError("MatchingResult needs mb or mb_packed")
        if L is None:
            if mb is None:
                # W*8 would silently invent up to 7 phantom substreams
                raise ValueError(
                    "L is required when only mb_packed is given "
                    "(the packed width cannot recover L when L % 8 != 0)"
                )
            L = mb.shape[-1]
        object.__setattr__(self, "assigned", assigned)
        object.__setattr__(self, "_mb", mb)
        object.__setattr__(self, "_mb_packed", mb_packed)
        object.__setattr__(self, "_L", int(L))

    def __setattr__(self, name, value):  # immutable, like the old frozen dataclass
        raise dataclasses.FrozenInstanceError(f"cannot assign to field {name!r}")

    @property
    def L(self) -> int:
        return self._L

    @property
    def mb(self) -> jax.Array:
        """bool [n, L] dense matching bits (lazily unpacked if packed)."""
        if self._mb is not None:
            return self._mb if self._mb.dtype == bool else self._mb.astype(bool)
        return bitpack.unpack_bits(self._mb_packed, self._L)

    @property
    def mb_packed(self) -> Optional[jax.Array]:
        """uint8 [n, ceil(L/8)] packed storage, or None if produced dense."""
        return self._mb_packed

    @property
    def is_packed(self) -> bool:
        return self._mb_packed is not None

    def packed(self) -> jax.Array:
        """uint8 [n, ceil(L/8)] packed bits (packing the dense view if needed)."""
        if self._mb_packed is not None:
            return self._mb_packed
        return bitpack.pack_bits(self.mb)

    def with_assigned(self, assigned) -> "MatchingResult":
        """Same bit storage, different ``assigned`` (e.g. un-permuted)."""
        return MatchingResult(
            assigned, mb=self._mb, mb_packed=self._mb_packed, L=self._L
        )

    def __repr__(self) -> str:
        store = "packed" if self.is_packed else "dense"
        return f"MatchingResult(assigned={self.assigned!r}, storage={store}, L={self._L})"


def _matching_result_flatten(r: MatchingResult):
    return (r.assigned, r._mb, r._mb_packed), (r._L,)


def _matching_result_unflatten(aux, children):
    assigned, mb, mb_packed = children
    obj = object.__new__(MatchingResult)
    object.__setattr__(obj, "assigned", assigned)
    object.__setattr__(obj, "_mb", mb)
    object.__setattr__(obj, "_mb_packed", mb_packed)
    object.__setattr__(obj, "_L", aux[0])
    return obj


jax.tree_util.register_pytree_node(
    MatchingResult, _matching_result_flatten, _matching_result_unflatten
)


def eligibility(weights: jax.Array, thresholds: jax.Array) -> jax.Array:
    """te[e, i] = w(e) >= (1+eps)^i — the L-bit eligibility vector (§4.4 Stage 4)."""
    return weights[:, None] >= thresholds[None, :]
