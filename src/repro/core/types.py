"""Core data types for the substream-centric matching framework.

An edge stream is a struct-of-arrays: ``src[i], dst[i], weight[i]`` in
*stream order* (the order the paper's FPGA would receive them). All
algorithms in :mod:`repro.core` treat the stream order as the greedy
priority order, exactly like Listing 1 of the paper.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class EdgeStream:
    """A weighted edge stream. ``src``/``dst`` are int32 [m], ``weight`` f32 [m].

    ``valid`` masks padding edges (False entries are ignored by every
    matcher); padding lets us keep shapes static under jit/shard_map.
    """

    src: jax.Array
    dst: jax.Array
    weight: jax.Array
    valid: jax.Array  # bool [m]

    @property
    def num_edges(self) -> int:
        return self.src.shape[0]

    @staticmethod
    def from_numpy(src, dst, weight, n_pad: Optional[int] = None) -> "EdgeStream":
        src = np.asarray(src, np.int32)
        dst = np.asarray(dst, np.int32)
        weight = np.asarray(weight, np.float32)
        m = src.shape[0]
        m_pad = m if n_pad is None else n_pad
        if m_pad < m:
            raise ValueError(f"pad {m_pad} < m {m}")
        pad = m_pad - m
        valid = np.concatenate([np.ones(m, bool), np.zeros(pad, bool)])
        z = np.zeros(pad, np.int32)
        return EdgeStream(
            src=jnp.asarray(np.concatenate([src, z])),
            dst=jnp.asarray(np.concatenate([dst, z])),
            weight=jnp.asarray(np.concatenate([weight, np.zeros(pad, np.float32)])),
            valid=jnp.asarray(valid),
        )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SubstreamConfig:
    """Parameters of the Crouch–Stubbs reduction.

    ``L`` substreams; substream ``i`` admits edges with
    ``w >= (1 + eps)**i``. The paper selects ``eps`` per L
    (Fig. 11 caption); we expose both knobs.
    """

    n: int = dataclasses.field(metadata=dict(static=True))
    L: int = dataclasses.field(metadata=dict(static=True))
    eps: float = dataclasses.field(default=0.1, metadata=dict(static=True))
    # Matching-bit storage layout: "packed" (uint8 bit planes, the §4.3
    # BRAM-word analogue — 8x the VMEM capacity) or "unpacked" (one int8
    # per bit; the legacy fallback). Consumed by kernels/substream_match.
    mb_layout: str = dataclasses.field(default="packed", metadata=dict(static=True))

    def thresholds(self) -> jax.Array:
        """[L] array of substream admission thresholds (1+eps)^i."""
        i = jnp.arange(self.L, dtype=jnp.float32)
        return (1.0 + self.eps) ** i

    @property
    def w_max(self) -> float:
        return float((1.0 + self.eps) ** self.L)


class MatchingResult:
    """Output of Part 1 (stream processing).

    ``assigned`` int32 [m]: the substream index whose list ``C[i]`` records
    the edge (the *highest* eligible substream where both endpoints were
    free), or -1 if the edge entered no list.

    The matching bits are held in ONE of two storages:

    * ``mb`` bool [n, L] — the dense view every pre-existing caller reads;
    * ``mb_packed`` uint8 [n, ceil(L/8)] — the bit-plane layout of
      :mod:`repro.core.bitpack` (the paper's §4.3 BRAM word).

    ``.mb`` is always readable: when only the packed storage is present it
    is unpacked lazily on access (outside any jit), so packed producers
    don't break dense consumers. ``.packed()`` is the mirror-image accessor.
    ``L`` (static) records the logical substream count; it is required to
    trim the last byte's padding bits when unpacking.
    """

    __slots__ = ("assigned", "_mb", "_mb_packed", "_L")

    def __init__(self, assigned, mb=None, mb_packed=None, L=None):
        if mb is None and mb_packed is None:
            raise ValueError("MatchingResult needs mb or mb_packed")
        if L is None:
            if mb is None:
                # W*8 would silently invent up to 7 phantom substreams
                raise ValueError(
                    "L is required when only mb_packed is given "
                    "(the packed width cannot recover L when L % 8 != 0)"
                )
            L = mb.shape[-1]
        object.__setattr__(self, "assigned", assigned)
        object.__setattr__(self, "_mb", mb)
        object.__setattr__(self, "_mb_packed", mb_packed)
        object.__setattr__(self, "_L", int(L))

    def __setattr__(self, name, value):  # immutable, like the old frozen dataclass
        raise dataclasses.FrozenInstanceError(f"cannot assign to field {name!r}")

    @property
    def L(self) -> int:
        return self._L

    @property
    def mb(self) -> jax.Array:
        """bool [n, L] dense matching bits (lazily unpacked if packed)."""
        if self._mb is not None:
            return self._mb if self._mb.dtype == bool else self._mb.astype(bool)
        return bitpack.unpack_bits(self._mb_packed, self._L)

    @property
    def mb_packed(self) -> Optional[jax.Array]:
        """uint8 [n, ceil(L/8)] packed storage, or None if produced dense."""
        return self._mb_packed

    @property
    def is_packed(self) -> bool:
        return self._mb_packed is not None

    def packed(self) -> jax.Array:
        """uint8 [n, ceil(L/8)] packed bits (packing the dense view if needed)."""
        if self._mb_packed is not None:
            return self._mb_packed
        return bitpack.pack_bits(self.mb)

    def with_assigned(self, assigned) -> "MatchingResult":
        """Same bit storage, different ``assigned`` (e.g. un-permuted)."""
        return MatchingResult(
            assigned, mb=self._mb, mb_packed=self._mb_packed, L=self._L
        )

    def __repr__(self) -> str:
        store = "packed" if self.is_packed else "dense"
        return f"MatchingResult(assigned={self.assigned!r}, storage={store}, L={self._L})"


def _matching_result_flatten(r: MatchingResult):
    return (r.assigned, r._mb, r._mb_packed), (r._L,)


def _matching_result_unflatten(aux, children):
    assigned, mb, mb_packed = children
    obj = object.__new__(MatchingResult)
    object.__setattr__(obj, "assigned", assigned)
    object.__setattr__(obj, "_mb", mb)
    object.__setattr__(obj, "_mb_packed", mb_packed)
    object.__setattr__(obj, "_L", aux[0])
    return obj


jax.tree_util.register_pytree_node(
    MatchingResult, _matching_result_flatten, _matching_result_unflatten
)


def eligibility(weights: jax.Array, thresholds: jax.Array) -> jax.Array:
    """te[e, i] = w(e) >= (1+eps)^i — the L-bit eligibility vector (§4.4 Stage 4)."""
    return weights[:, None] >= thresholds[None, :]
