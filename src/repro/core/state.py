"""Resumable matching state — the paper's key robustness property.

The semi-streaming formulation keeps *all* algorithm state in a tiny
per-vertex bit block (``mb[n, ceil(L/8)]``) plus the recorded-edge
prefix of ``assigned``, updated by one sequential pass over the edge
stream. That makes the computation checkpointable at any stream
position: :class:`MatchState` is exactly that state plus a config
fingerprint, and the epoch executor
(:func:`repro.kernels.substream_match.ops.match_epochs`) threads it
through the engines — a run resumed from a snapshot is bit-identical
to the uninterrupted run because greedy matching is confluent in the
carried bits (see docs/paper_map.md).

``MatchState`` is host-side (numpy) by design: snapshots must not
capture device buffers, and the epoch driver's carry is consumed on
the host between device calls anyway.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from repro.core import bitpack
from repro.core.types import EdgeStream, MatchingResult, SubstreamConfig

#: Format version stamped into snapshots; bump on layout changes so a
#: stale snapshot fails loudly instead of deserializing garbage.
STATE_VERSION = 1


def fingerprint_for(
    stream: EdgeStream, cfg: SubstreamConfig, packed: bool
) -> str:
    """Content hash binding a state to (stream, cfg, storage layout).

    Resuming against a different stream or config would silently
    produce a wrong matching — the fingerprint turns that into a
    structured :class:`repro.checkpoint.snapshots.SnapshotMismatchError`
    at restore time. sha256 over the config scalars and the raw bytes
    of the stream arrays, truncated to 16 hex chars (64 bits — plenty
    for corruption/mix-up detection, not a security boundary).
    """
    h = hashlib.sha256()
    h.update(
        f"v{STATE_VERSION}|n={cfg.n}|L={cfg.L}|eps={cfg.eps!r}|"
        f"packed={bool(packed)}|m={stream.num_edges}|".encode()
    )
    for arr in (stream.src, stream.dst, stream.weight, stream.valid):
        h.update(np.ascontiguousarray(np.asarray(arr)).tobytes())
    return h.hexdigest()[:16]


@dataclasses.dataclass(frozen=True)
class MatchState:
    """Everything Part 1 needs to continue from stream position ``pos``.

    ``assigned`` holds the per-edge substream assignment for the
    consumed prefix (``-1`` beyond ``pos``), ``mb`` the matching-bit
    block in the run's storage layout (uint8 ``[n, ceil(L/8)]`` packed /
    bool ``[n, L]`` dense), and ``recorded_counts`` the per-substream
    recorded-edge cursors ``|C_i|`` — redundant with ``assigned`` by
    construction, which is exactly why they are stored: :meth:`problems`
    recomputes them and a torn or mixed-up snapshot fails the check.
    """

    fingerprint: str
    pos: int
    num_edges: int
    n: int
    L: int
    packed: bool
    assigned: np.ndarray  # int32 [num_edges]; -1 beyond pos
    mb: np.ndarray  # uint8 [n, W] packed / bool [n, L] dense
    recorded_counts: np.ndarray  # int64 [L]

    # ------------------------------------------------------------ build

    @staticmethod
    def initial(
        stream: EdgeStream, cfg: SubstreamConfig, packed: bool
    ) -> "MatchState":
        """The pos-0 zero state for a fresh run."""
        words = bitpack.packed_width(max(cfg.L, 1))
        mb = (
            np.zeros((cfg.n, words), np.uint8)
            if packed
            else np.zeros((cfg.n, cfg.L), bool)
        )
        return MatchState(
            fingerprint=fingerprint_for(stream, cfg, packed),
            pos=0,
            num_edges=stream.num_edges,
            n=cfg.n,
            L=cfg.L,
            packed=bool(packed),
            assigned=np.full(stream.num_edges, -1, np.int32),
            mb=mb,
            recorded_counts=np.zeros(cfg.L, np.int64),
        )

    # ---------------------------------------------------------- advance

    def advance(self, result: MatchingResult, end: int) -> "MatchState":
        """Fold one epoch's result (edges ``[pos, end)``) into the state.

        ``result`` is the engine output for the epoch slice run with
        ``mb0 = self.mb``; its ``assigned`` covers ``end - pos`` edges
        and its bit block *replaces* the carried one (the engines carry
        it through, so it is the cumulative block, not a delta).
        """
        if not self.pos <= end <= self.num_edges:
            raise ValueError(f"epoch end {end} outside [{self.pos}, {self.num_edges}]")
        epoch_assigned = np.asarray(result.assigned, np.int32)
        if epoch_assigned.shape != (end - self.pos,):
            raise ValueError(
                f"epoch result covers {epoch_assigned.shape} edges, "
                f"expected {(end - self.pos,)}"
            )
        assigned = self.assigned.copy()
        assigned[self.pos : end] = epoch_assigned
        hits = epoch_assigned[epoch_assigned >= 0]
        counts = self.recorded_counts + np.bincount(
            hits, minlength=self.L
        ).astype(np.int64)
        mb = (
            np.asarray(result.mb_packed, np.uint8)
            if self.packed
            else np.asarray(result.mb, bool)
        )
        return dataclasses.replace(
            self, pos=int(end), assigned=assigned, recorded_counts=counts, mb=mb
        )

    # ------------------------------------------------------------ views

    @property
    def done(self) -> bool:
        return self.pos == self.num_edges

    @property
    def mb0(self) -> np.ndarray | None:
        """The carried bit block as substream_match's ``mb0`` operand
        (``None`` at pos 0 — keeps the fresh run on the zero-state jit
        variants, byte-identical to a non-resumable call)."""
        return None if self.pos == 0 else self.mb

    def result(self) -> MatchingResult:
        """The completed run as a :class:`MatchingResult` (requires
        ``done``; a partial state has no meaningful matching yet)."""
        if not self.done:
            raise ValueError(
                f"run incomplete: pos {self.pos} of {self.num_edges} edges"
            )
        if self.packed:
            return MatchingResult(
                assigned=self.assigned, mb_packed=self.mb, L=self.L
            )
        return MatchingResult(assigned=self.assigned, mb=self.mb)

    # -------------------------------------------------------- integrity

    def problems(self) -> list[str]:
        """Structural integrity check; [] when consistent.

        Shape/dtype/range checks plus the redundancy check: the
        recorded-count cursors must equal a recount of ``assigned`` —
        a torn snapshot (bit block from one epoch, assigned from
        another) fails here even though each array alone looks fine.
        """
        out = []
        words = bitpack.packed_width(max(self.L, 1))
        want_mb = (self.n, words) if self.packed else (self.n, self.L)
        if tuple(self.mb.shape) != want_mb:
            out.append(f"mb shape {self.mb.shape} != {want_mb}")
        if self.assigned.shape != (self.num_edges,):
            out.append(
                f"assigned shape {self.assigned.shape} != {(self.num_edges,)}"
            )
        if not 0 <= self.pos <= self.num_edges:
            out.append(f"pos {self.pos} outside [0, {self.num_edges}]")
            return out
        if self.assigned.size:
            lo = int(self.assigned.min())
            hi = int(self.assigned.max())
            if lo < -1 or hi >= self.L:
                out.append(f"assigned values [{lo}, {hi}] outside [-1, {self.L})")
        if (self.assigned[self.pos :] != -1).any():
            out.append("assigned set beyond pos")
        if self.recorded_counts.shape != (self.L,):
            out.append(
                f"recorded_counts shape {self.recorded_counts.shape} != {(self.L,)}"
            )
        else:
            prefix = self.assigned[: self.pos]
            hits = prefix[prefix >= 0]
            want = np.bincount(hits, minlength=self.L).astype(np.int64)
            if not np.array_equal(want, self.recorded_counts):
                out.append("recorded_counts disagree with assigned recount")
        return out

    # ------------------------------------------------------ persistence

    def to_arrays(self) -> dict[str, np.ndarray]:
        """The array payload for :func:`repro.checkpoint.save_pytree`.
        ``mb`` is stored as uint8 either way (npz round-trips bool fine,
        but a fixed on-disk dtype keeps the format stable)."""
        return {
            "assigned": self.assigned,
            "mb": self.mb.astype(np.uint8),
            "recorded_counts": self.recorded_counts,
        }

    def metadata(self) -> dict:
        """The JSON-safe scalars for the snapshot manifest."""
        return {
            "state_version": STATE_VERSION,
            "fingerprint": self.fingerprint,
            "pos": int(self.pos),
            "num_edges": int(self.num_edges),
            "n": int(self.n),
            "L": int(self.L),
            "packed": bool(self.packed),
        }

    @staticmethod
    def from_arrays(meta: dict, arrays: dict) -> "MatchState":
        """Rebuild from :meth:`metadata` + :meth:`to_arrays` payloads."""
        packed = bool(meta["packed"])
        mb = np.asarray(arrays["mb"], np.uint8)
        return MatchState(
            fingerprint=str(meta["fingerprint"]),
            pos=int(meta["pos"]),
            num_edges=int(meta["num_edges"]),
            n=int(meta["n"]),
            L=int(meta["L"]),
            packed=packed,
            assigned=np.asarray(arrays["assigned"], np.int32),
            mb=mb if packed else mb.astype(bool),
            recorded_counts=np.asarray(arrays["recorded_counts"], np.int64),
        )
