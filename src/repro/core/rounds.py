"""Deterministic propose–accept parallel matching (beyond-paper scaling path).

Greedy maximal matching w.r.t. a fixed total edge order is unique and equals
the "repeatedly take all locally-minimal live edges" fixed point (parallel
greedy / lexicographically-first matching). We exploit this twice:

 * single device: replaces the sequential O(m) scan by O(#rounds) passes of
   vectorized segment-mins — each pass is pure VPU/MXU-friendly bulk work;
 * multi device: edges shard over the ``data`` axis, substream blocks over
   ``model``; one ``psum``-min per round resolves cross-partition conflicts.

Output is bit-identical to :func:`repro.core.matching.mwm_scan` (tested).
The priority order is the stream position, i.e. exactly Listing 1's order.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.core import bitpack
from repro.core.types import EdgeStream, MatchingResult, SubstreamConfig

_INF = jnp.iinfo(jnp.int32).max


def _vertex_min(pri_el: jax.Array, src, dst, n: int) -> jax.Array:
    """[n, L] min over live incident-edge priorities (INF where none)."""
    best = jnp.full((n,) + pri_el.shape[1:], _INF, jnp.int32)
    best = best.at[src].min(pri_el)
    best = best.at[dst].min(pri_el)
    return best


def mwm_rounds(
    stream: EdgeStream, cfg: SubstreamConfig, max_rounds: int = 0,
    packed: bool = False, waves=None, telemetry=obs.DISABLED,
) -> MatchingResult:
    """Parallel-rounds equivalent of Listing 1 Part 1 (single device).

    ``packed=True`` ships the final bits in the uint8 bit-plane storage of
    :mod:`repro.core.bitpack` (8x smaller to keep/checkpoint/transfer);
    the round state itself stays bool — the conflict resolution needs
    per-substream scatters, not bitwise words. Unpacking the result is
    bit-identical to the dense output.

    ``waves`` (a :class:`repro.graph.waves.WaveSchedule`) swaps the
    propose–accept fixed point for per-segment updates: instead of
    ``O(#rounds)`` passes that each run a full-[m, L] liveness mask and
    a full-[n, L] ``.at[].min`` vertex reduction, the fill-packed slot
    layout lets each step touch exactly one conflict-free [SEG, L]
    segment — no conflict resolution needed, because a wave *is* the set
    of edges the fixed point would accept given all earlier waves.
    Output is identical either way.

    ``telemetry`` records the call: the wave path delegates to
    :func:`repro.core.matching.mwm_waves` (whose ``waves_xla`` record
    covers the run), the fixed point records one ``rounds`` record whose
    device stage is the whole while-loop dispatch.
    """
    if waves is not None:
        if max_rounds:
            raise ValueError(
                "max_rounds only applies to the propose-accept fixed point; "
                "the wave path always computes the full matching"
            )
        from repro.core import matching as _matching

        res = _matching.mwm_waves(
            stream, cfg, schedule=waves, telemetry=telemetry
        )
        if packed:
            return MatchingResult(
                assigned=res.assigned, mb_packed=bitpack.pack_bits(res.mb),
                L=cfg.L,
            )
        return res
    rec = obs.recorder(
        telemetry, "rounds", stream.num_edges, jax.default_backend()
    )
    if telemetry.enabled:
        rec.put("stream.num_edges", stream.num_edges)
        rec.put("rounds.max_rounds", int(max_rounds))
    key = ("rounds", cfg.n, cfg.L, cfg.eps, max_rounds, packed,
           stream.num_edges)
    with rec.device_stage(key):
        out = _mwm_rounds_fixed_point(stream, cfg, max_rounds, packed)
        rec.block(out)
    rec.finish()
    return out


@partial(jax.jit, static_argnames=("cfg", "max_rounds", "packed"))
def _mwm_rounds_fixed_point(
    stream: EdgeStream, cfg: SubstreamConfig, max_rounds: int = 0,
    packed: bool = False,
) -> MatchingResult:
    thr = cfg.thresholds()
    m = stream.num_edges
    src = stream.src.astype(jnp.int32)
    dst = stream.dst.astype(jnp.int32)
    te = (stream.weight[:, None] >= thr[None, :]) & stream.valid[:, None]
    te &= (src != dst)[:, None]  # self-loops never join a matching
    pri = jnp.arange(m, dtype=jnp.int32)

    def cond(state):
        alive, _, _, it = state
        cap = jnp.int32(max_rounds) if max_rounds else jnp.int32(m + 1)
        return jnp.any(alive) & (it < cap)

    def body(state):
        alive, added, mb, it = state
        pri_el = jnp.where(alive, pri[:, None], _INF)
        best = _vertex_min(pri_el, src, dst, cfg.n)
        win = alive & (best[src] == pri_el) & (best[dst] == pri_el)
        mb = mb.at[src].max(win)
        mb = mb.at[dst].max(win)
        added |= win
        alive &= ~(mb[src] | mb[dst])
        return alive, added, mb, it + 1

    alive0 = te
    added0 = jnp.zeros((m, cfg.L), bool)
    mb0 = jnp.zeros((cfg.n, cfg.L), bool)
    _, added, mb, rounds = jax.lax.while_loop(
        cond, body, (alive0, added0, mb0, jnp.int32(0))
    )
    assigned = jnp.where(
        added, jax.lax.broadcasted_iota(jnp.int32, added.shape, 1), -1
    ).max(axis=1)
    if packed:
        return MatchingResult(
            assigned=assigned, mb_packed=bitpack.pack_bits(mb), L=cfg.L
        )
    return MatchingResult(assigned=assigned, mb=mb)


def mwm_rounds_sharded(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    mesh,
    edge_axis: str = "data",
    substream_axis: str = "model",
):
    """Distributed rounds: edges sharded over ``edge_axis``, substreams over
    ``substream_axis``. Every device holds the full [n, L_local] bit block
    for its substream slice; cross-edge-partition conflicts are resolved by
    one `psum`-min per round. Returns a :class:`MatchingResult` with global
    (replicated-over-edge-axis) ``mb`` and edge-sharded ``assigned``.
    """
    thr_full = cfg.thresholds()

    def local(src, dst, w, valid, thr):
        m_loc = src.shape[0]
        # jax.lax.axis_size only exists in newer jax; psum(1) is portable
        n_edge_shards = jax.lax.psum(jnp.int32(1), edge_axis)
        shard_id = jax.lax.axis_index(edge_axis)
        # global stream position = shard_id * m_loc + local position
        pri = (shard_id * m_loc + jnp.arange(m_loc)).astype(jnp.int32)
        te = (w[:, None] >= thr[None, :]) & valid[:, None] & (src != dst)[:, None]
        L_loc = thr.shape[0]

        def cond(state):
            alive, _, _, it = state
            any_alive = jax.lax.psum(jnp.any(alive).astype(jnp.int32), edge_axis)
            return (any_alive > 0) & (it < n_edge_shards * m_loc + 1)

        def body(state):
            alive, added, mb, it = state
            pri_el = jnp.where(alive, pri[:, None], _INF)
            best = _vertex_min(pri_el, src, dst, cfg.n)
            best = jax.lax.pmin(best, edge_axis)
            win = alive & (best[src] == pri_el) & (best[dst] == pri_el)
            mb_new = jnp.zeros_like(mb).at[src].max(win).at[dst].max(win)
            mb = mb | (jax.lax.pmax(mb_new.astype(jnp.int8), edge_axis) > 0)
            added |= win
            alive &= ~(mb[src] | mb[dst])
            return alive, added, mb, it + 1

        alive0 = te
        added0 = jnp.zeros((m_loc, L_loc), bool)
        mb0 = jnp.zeros((cfg.n, L_loc), bool)
        _, added, mb, _ = jax.lax.while_loop(
            cond, body, (alive0, added0, mb0, jnp.int32(0))
        )
        base = jax.lax.axis_index(substream_axis) * L_loc
        assigned = jnp.where(
            added, base + jax.lax.broadcasted_iota(jnp.int32, added.shape, 1), -1
        ).max(axis=1)
        # global max over substream shards: each edge recorded in its highest
        assigned = jax.lax.pmax(assigned, substream_axis)
        return assigned, mb

    from jax.experimental.shard_map import shard_map

    fn = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(edge_axis),
            P(edge_axis),
            P(edge_axis),
            P(edge_axis),
            P(substream_axis),
        ),
        out_specs=(P(edge_axis), P(None, substream_axis)),
        check_rep=False,
    )
    return fn(stream.src, stream.dst, stream.weight, stream.valid, thr_full)
