"""Exact MWM oracle (networkx blossom) — test/benchmark reference only."""
from __future__ import annotations

import numpy as np

from repro.core.types import EdgeStream


def exact_mwm_weight(stream: EdgeStream) -> float:
    import networkx as nx

    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    w = np.asarray(stream.weight)
    valid = np.asarray(stream.valid)
    g = nx.Graph()
    for u, v, wt, ok in zip(src, dst, w, valid):
        if not ok or u == v:
            continue
        # parallel edges: keep the max weight (a matching would pick it)
        if g.has_edge(int(u), int(v)):
            g[int(u)][int(v)]["weight"] = max(g[int(u)][int(v)]["weight"], float(wt))
        else:
            g.add_edge(int(u), int(v), weight=float(wt))
    m = nx.max_weight_matching(g, maxcardinality=False)
    return float(sum(g[u][v]["weight"] for u, v in m))
