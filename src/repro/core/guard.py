"""Guarded execution layer — input validation and matching invariants.

The paper's guarantees ((2+eps) per-substream competitiveness, the
(4+eps) merged bound, bounded storage, predictable per-edge throughput)
only hold for *well-formed* inputs: vertex ids in ``[0, n)``, finite
non-negative weights, a stream whose padding edges are masked. Outside
that envelope the engines do not fail loudly — an out-of-range id
becomes an out-of-bounds row scatter (XLA clamps, the Pallas kernels
hit the sacrificial padding row or a neighbour's row), an Inf weight
matches every substream, a NaN silently never matches — exactly the
clean-benchmark-vs-dirty-reality gap the FPGA survey (Besta et al.)
calls out.

This module is the guard between untrusted streams and the matching
core:

* :func:`validate_stream` — pre-condition check with three policies:
  ``strict`` (raise a structured :class:`StreamValidationError` listing
  the offending stream positions), ``sanitize`` (drop the bad edges,
  report what was dropped through telemetry counters), and ``off``
  (today's behavior — zero overhead, for trusted benchmark paths).
* :func:`check_matching` / :func:`matching_problems` — post-condition
  check of a :class:`~repro.core.types.MatchingResult` against the
  stream it claims to describe: recorded edges exist, are eligible for
  their substream, each vertex is matched at most once per substream,
  the matching bits agree with the recorded lists, and (optionally) the
  merged weight honours the (4+eps) bound against an exact optimum.

Everything here is host-side numpy — O(m) passes that are negligible
next to a kernel launch and run zero times under ``policy="off"``.
The fallback cascade that consumes these guards lives in
:mod:`repro.kernels.substream_match.ops` (``on_plan_failure=``);
the fault injector that proves they fire lives in
:mod:`repro.testing.faultline`.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs

#: Accepted validation policies, in decreasing strictness.
POLICIES = ("strict", "sanitize", "off")

#: How many offending stream positions a problem reports (the count is
#: always exact; the index list is a sample so errors stay readable on
#: million-edge streams).
MAX_REPORT_INDICES = 16


@dataclasses.dataclass(frozen=True)
class StreamProblem:
    """One class of input fault found in a stream.

    ``kind`` is a stable machine-readable tag (see the failure taxonomy
    in ``docs/robustness.md``), ``count`` the exact number of offending
    valid edges, ``indices`` the first :data:`MAX_REPORT_INDICES`
    offending stream positions.
    """

    kind: str
    count: int
    indices: tuple
    detail: str = ""

    def __str__(self) -> str:
        idx = list(self.indices)
        more = "" if self.count <= len(idx) else f" (+{self.count - len(idx)} more)"
        detail = f" — {self.detail}" if self.detail else ""
        return f"{self.kind}: {self.count} edge(s) at positions {idx}{more}{detail}"


class StreamValidationError(ValueError):
    """Strict-policy rejection of a malformed edge stream.

    ``problems`` holds the structured :class:`StreamProblem` list; the
    message enumerates every kind with counts and sample positions, so
    a service log names the fault without a debugger.
    """

    def __init__(self, problems, n=None):
        self.problems = tuple(problems)
        where = "" if n is None else f" (vertex space [0, {n}))"
        msg = "invalid edge stream" + where + ": " + "; ".join(
            str(p) for p in self.problems
        )
        super().__init__(msg)


class MatchingInvariantError(ValueError):
    """A :class:`~repro.core.types.MatchingResult` violates a Part-1
    postcondition (see :func:`matching_problems` for the checks)."""

    def __init__(self, problems):
        self.problems = tuple(problems)
        super().__init__(
            "matching result violates invariants: " + "; ".join(self.problems)
        )


@dataclasses.dataclass(frozen=True)
class ValidationReport:
    """What :func:`validate_stream` saw (and, under ``sanitize``, did).

    ``num_valid_in`` counts the valid edges before the policy ran,
    ``num_dropped`` how many of them ``sanitize`` masked out (always 0
    under ``strict``/``off`` — strict raises instead of dropping).
    """

    policy: str
    n: int
    num_edges: int
    num_valid_in: int
    num_dropped: int
    problems: tuple = ()

    @property
    def ok(self) -> bool:
        return not self.problems

    @property
    def degenerate(self) -> bool:
        """True when nothing can ever match (no valid edges, or n < 2)."""
        return self.num_valid_in - self.num_dropped == 0 or self.n < 2

    def counters(self) -> dict:
        """The ``guard.*`` counter set (bench JSON / telemetry)."""
        out = {
            "guard.num_edges": int(self.num_edges),
            "guard.num_valid_in": int(self.num_valid_in),
            "guard.dropped_edges": int(self.num_dropped),
            "guard.num_problems": int(len(self.problems)),
        }
        for p in self.problems:
            out[f"guard.fault.{p.kind}"] = int(p.count)
        return out


def _problem(kind: str, mask: np.ndarray, detail: str = "") -> StreamProblem:
    idx = np.nonzero(mask)[0]
    return StreamProblem(
        kind=kind,
        count=int(idx.size),
        indices=tuple(int(i) for i in idx[:MAX_REPORT_INDICES]),
        detail=detail,
    )


def stream_problems(src, dst, weight, valid, n: int) -> list[StreamProblem]:
    """Pure fault detector: numpy arrays in, :class:`StreamProblem` list out.

    Only *valid* (non-padding) edges are examined — padding edges are a
    legitimate encoding, whatever garbage their slots hold. Checks, in
    taxonomy order:

    * ``empty_vertex_space`` — valid edges exist but ``n < 1`` (no row
      of the bit block can legally be addressed);
    * ``id_out_of_range`` — an endpoint outside ``[0, n)``. This covers
      negative ids, ids at/after ``n`` (silent row clamping under XLA),
      and in particular the sacrificial padding row ``n_pad >= n`` the
      Pallas kernels scatter padding slots to — a colliding real edge
      would alias it;
    * ``nonfinite_weight`` — NaN or ±Inf (+Inf matches *every*
      substream; NaN silently never matches; both void the (2+eps)
      analysis);
    * ``negative_weight`` — finite ``w < 0`` (weights below every
      threshold never match, but negative weights additionally break
      the merged-weight accounting and signal caller corruption).
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    weight = np.asarray(weight)
    valid = np.asarray(valid, bool)
    problems: list[StreamProblem] = []
    if not valid.any():
        return problems
    if n < 1:
        problems.append(
            _problem("empty_vertex_space", valid, detail=f"n = {n}")
        )
        return problems
    bad_id = valid & (
        (src < 0) | (src >= n) | (dst < 0) | (dst >= n)
    )
    if bad_id.any():
        problems.append(
            _problem("id_out_of_range", bad_id, detail=f"ids must be in [0, {n})")
        )
    with np.errstate(invalid="ignore"):
        nonfinite = valid & ~np.isfinite(weight)
        negative = valid & np.isfinite(weight) & (weight < 0)
    if nonfinite.any():
        problems.append(_problem("nonfinite_weight", nonfinite))
    if negative.any():
        problems.append(_problem("negative_weight", negative))
    return problems


def validate_stream(
    stream,
    n: int,
    policy: str = "strict",
    telemetry=obs.DISABLED,
):
    """Validate (and under ``sanitize`` repair) an edge stream for ``n`` vertices.

    Returns ``(stream, report)``:

    * ``policy="off"`` — no checks at all (today's behavior; the
      returned stream *is* the input, the report is empty). Default for
      trusted benchmark paths, where the O(m) pass would be pure
      overhead.
    * ``policy="strict"`` — raise :class:`StreamValidationError` naming
      every fault kind with counts and sample stream positions; the
      stream passes through untouched when clean.
    * ``policy="sanitize"`` — mask every faulty edge out of ``valid``
      (dropping, never clamping: a clamped id or weight would silently
      change which edges can match) and report what was dropped via the
      ``guard.*`` telemetry counters plus a ``guard.sanitize`` event.

    The returned stream always satisfies the engines' preconditions
    (under ``off`` that is the caller's promise, not a checked fact).
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown validation policy {policy!r}; use one of {POLICIES}")
    m = stream.num_edges
    if policy == "off":
        return stream, ValidationReport(
            policy=policy, n=n, num_edges=m, num_valid_in=-1, num_dropped=0
        )
    with telemetry.span("guard.validate", policy=policy):
        src = np.asarray(stream.src)
        dst = np.asarray(stream.dst)
        weight = np.asarray(stream.weight)
        valid = np.asarray(stream.valid, bool)
        num_valid_in = int(valid.sum())
        problems = stream_problems(src, dst, weight, valid, n)
    if telemetry.enabled:
        telemetry.counters.add("guard.validate.calls")
    if not problems:
        report = ValidationReport(
            policy=policy, n=n, num_edges=m, num_valid_in=num_valid_in,
            num_dropped=0,
        )
        if telemetry.enabled:
            telemetry.counters.update(report.counters())
        return stream, report
    if policy == "strict":
        if telemetry.enabled:
            telemetry.event(
                "guard.reject",
                policy=policy,
                kinds=[p.kind for p in problems],
                bad_edges=sum(p.count for p in problems),
            )
            telemetry.counters.add("guard.rejected_streams")
        raise StreamValidationError(problems, n=n)

    # sanitize: drop every faulty edge (valid=False), zero its slots so
    # downstream host paths see the same benign encoding padding uses
    bad = np.zeros(m, bool)
    if n < 1:
        bad |= valid
    else:
        bad |= valid & ((src < 0) | (src >= n) | (dst < 0) | (dst >= n))
        with np.errstate(invalid="ignore"):
            bad |= valid & (~np.isfinite(weight) | (weight < 0))
    import jax.numpy as jnp

    from repro.core.types import EdgeStream

    keep = valid & ~bad
    clean = EdgeStream(
        src=jnp.asarray(np.where(bad, 0, src).astype(np.int32)),
        dst=jnp.asarray(np.where(bad, 0, dst).astype(np.int32)),
        weight=jnp.asarray(
            np.where(bad, 0.0, weight).astype(np.float32)
        ),
        valid=jnp.asarray(keep),
    )
    report = ValidationReport(
        policy=policy, n=n, num_edges=m, num_valid_in=num_valid_in,
        num_dropped=int(bad.sum()), problems=tuple(problems),
    )
    if telemetry.enabled:
        telemetry.counters.update(report.counters())
        telemetry.event(
            "guard.sanitize",
            dropped=report.num_dropped,
            kinds=[p.kind for p in problems],
        )
    return clean, report


# ---------------------------------------------------------------------------
# Postcondition: matching-result invariants
# ---------------------------------------------------------------------------


def matching_problems(
    result, stream, cfg, merged=None, exact_weight=None
) -> list[str]:
    """Check a Part-1 result (and optionally a Part-2 merge) against the
    stream it claims to describe. Returns human-readable problem strings
    (empty = every invariant holds). The checks:

    1. ``assigned`` has shape ``[m]`` with values in ``[-1, L)``;
    2. every recorded edge (``assigned >= 0``) is a valid, non-self-loop
       stream edge with in-range endpoints;
    3. eligibility: a recorded edge's weight reaches its substream's
       threshold ``(1+eps)^i``;
    4. each vertex is matched at most once per substream — the recorded
       list of substream ``i`` is a subset of the matching ``M_i``, so
       it must be vertex-disjoint;
    5. the matching bits agree: a recorded edge at substream ``i`` set
       ``mb[u, i]`` and ``mb[v, i]``;
    6. (``merged`` given — stream positions of the Part-2 output ``T``)
       the merge picked recorded edges only, each at most once, and
       vertex-disjoint overall;
    7. (``exact_weight`` given as well) the merged weight honours the
       composed Crouch–Stubbs bound ``w(M*)/w(T) <= 4 + eps``.

    Pure numpy, O(m + R·L/8); never raises — :func:`check_matching` is
    the raising wrapper.
    """
    problems: list[str] = []
    m = stream.num_edges
    assigned = np.asarray(result.assigned)
    if assigned.shape != (m,):
        problems.append(
            f"assigned shape {assigned.shape} != stream shape ({m},)"
        )
        return problems
    out_of_range = (assigned < -1) | (assigned >= cfg.L)
    if out_of_range.any():
        idx = np.nonzero(out_of_range)[0][:MAX_REPORT_INDICES]
        problems.append(
            f"assigned out of range [-1, {cfg.L}) at positions {idx.tolist()}"
        )
        return problems
    rec = np.nonzero(assigned >= 0)[0]
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    weight = np.asarray(stream.weight)
    valid = np.asarray(stream.valid, bool)
    if rec.size:
        not_valid = rec[~valid[rec]]
        if not_valid.size:
            problems.append(
                f"recorded edges at padding/invalid positions "
                f"{not_valid[:MAX_REPORT_INDICES].tolist()}"
            )
        u, v = src[rec], dst[rec]
        loops = rec[u == v]
        if loops.size:
            problems.append(
                f"recorded self-loops at positions "
                f"{loops[:MAX_REPORT_INDICES].tolist()}"
            )
        oob = rec[(u < 0) | (u >= cfg.n) | (v < 0) | (v >= cfg.n)]
        if oob.size:
            problems.append(
                f"recorded edges with endpoints outside [0, {cfg.n}) at "
                f"positions {oob[:MAX_REPORT_INDICES].tolist()}"
            )
            return problems  # the mb/disjointness checks index by vertex
        thr = np.asarray(cfg.thresholds())  # the engines' own float32 values
        with np.errstate(invalid="ignore"):
            below = ~(weight[rec].astype(np.float32) >= thr[assigned[rec]])
        if below.any():
            bad = rec[below]
            problems.append(
                f"recorded edges below their substream threshold at "
                f"positions {bad[:MAX_REPORT_INDICES].tolist()}"
            )
        # vertex matched <= once per substream: fuse (substream, vertex)
        # into one int64 key over both endpoints; duplicates = conflicts
        i64 = assigned[rec].astype(np.int64)
        keep = u != v
        keys = np.concatenate(
            [i64 * cfg.n + u.astype(np.int64), (i64 * cfg.n + v.astype(np.int64))[keep]]
        )
        uniq, counts = np.unique(keys, return_counts=True)
        dup = uniq[counts > 1]
        if dup.size:
            sample = [
                (int(k // cfg.n), int(k % cfg.n))
                for k in dup[:MAX_REPORT_INDICES]
            ]
            problems.append(
                f"vertex matched more than once in a substream "
                f"(substream, vertex) pairs {sample}"
            )
        mb = np.asarray(result.mb)
        if mb.shape != (cfg.n, cfg.L):
            problems.append(f"mb shape {mb.shape} != ({cfg.n}, {cfg.L})")
        else:
            unset = ~(mb[u, assigned[rec]] & mb[v, assigned[rec]])
            if unset.any():
                bad = rec[unset]
                problems.append(
                    f"matching bit not set for recorded edges at positions "
                    f"{bad[:MAX_REPORT_INDICES].tolist()}"
                )
    if merged is not None:
        merged = np.asarray(merged)
        if merged.size:
            if (merged < 0).any() or (merged >= m).any():
                problems.append("merged indices outside the stream")
                return problems
            if np.unique(merged).size != merged.size:
                problems.append("merged picks a stream position twice")
            un_rec = merged[assigned[merged] < 0]
            if un_rec.size:
                problems.append(
                    f"merged edges that were never recorded at positions "
                    f"{un_rec[:MAX_REPORT_INDICES].tolist()}"
                )
            mu, mv = src[merged], dst[merged]
            ends = np.concatenate([mu, mv])
            uniq, counts = np.unique(ends, return_counts=True)
            if (counts > 1).any():
                problems.append(
                    f"merged matching not vertex-disjoint at vertices "
                    f"{uniq[counts > 1][:MAX_REPORT_INDICES].tolist()}"
                )
        if exact_weight is not None:
            got = float(weight[merged].sum()) if merged.size else 0.0
            if exact_weight > 0 and got <= 0:
                problems.append(
                    f"merged weight {got} but exact optimum {exact_weight}"
                )
            elif got > 0 and exact_weight / got > 4 + cfg.eps + 1e-3:
                problems.append(
                    f"merged weight {got:.6g} violates the (4+eps) bound "
                    f"against exact {exact_weight:.6g} "
                    f"(ratio {exact_weight / got:.4f})"
                )
    return problems


def check_matching(
    result, stream, cfg, merged=None, exact_weight=None, telemetry=obs.DISABLED
) -> None:
    """Raise :class:`MatchingInvariantError` unless every postcondition of
    :func:`matching_problems` holds. Records one ``guard.check_matching``
    span + the ``guard.invariant_violations`` counter when telemetry is
    enabled."""
    with telemetry.span("guard.check_matching"):
        problems = matching_problems(
            result, stream, cfg, merged=merged, exact_weight=exact_weight
        )
    if telemetry.enabled:
        telemetry.counters.add("guard.check_matching.calls")
        if problems:
            telemetry.counters.add("guard.invariant_violations", len(problems))
            telemetry.event("guard.invariant_violation", problems=problems)
    if problems:
        raise MatchingInvariantError(problems)
