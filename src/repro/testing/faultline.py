"""Deterministic fault injection for the guarded matching pipeline.

Test-only machinery (used by ``tests/test_faults.py``) that manufactures
the failure modes the guard layer (:mod:`repro.core.guard`) and the
fallback cascade (``substream_match(..., on_plan_failure="fallback")``)
claim to handle:

* **input faults** — :func:`poison_ids` / :func:`poison_weights` plant
  out-of-range ids (including the sacrificial padding row ``n_pad``) and
  NaN/Inf/negative weights at chosen stream positions;
* **result corruptions** — :func:`corrupt_assigned` rewrites ``assigned``
  entries, :func:`flip_matching_bit` flips one bit of the (packed or
  dense) bit-plane block;
* **schedule faults** — :func:`truncate_schedule` / :func:`permute_schedule`
  produce the stale/corrupted precomputed schedules
  ``repro.graph.waves.validate_schedule`` exists to reject;
* **plan / compile faults** — :func:`failing` monkey-patches the named
  ``ops`` internals (planners or jitted device entries) to raise, forcing
  the cascade to degrade.

Everything is pure and deterministic: no RNG, no wall clock — the same
call always injects the same fault, so a failing test replays exactly.
"""
from __future__ import annotations

import contextlib
import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import bitpack
from repro.core.types import EdgeStream, MatchingResult


@dataclasses.dataclass(frozen=True)
class InjectedFault:
    """What was planted: the guard taxonomy ``kind`` expected to flag it,
    the stream positions touched, and a human-readable description."""

    kind: str
    positions: tuple
    description: str


def _replace(stream: EdgeStream, **arrays) -> EdgeStream:
    fields = {
        "src": np.asarray(stream.src).copy(),
        "dst": np.asarray(stream.dst).copy(),
        "weight": np.asarray(stream.weight).copy(),
        "valid": np.asarray(stream.valid).copy(),
    }
    fields.update(arrays)
    return EdgeStream(**{k: jnp.asarray(v) for k, v in fields.items()})


def sacrificial_row(n: int) -> int:
    """The padding row id the row-addressed kernels scatter padding slots
    to (``vmem_plan``'s ``n_pad``) — an id a dirty input could collide
    with. Mirrors ``ops.vmem_plan``'s rounding so the injector does not
    import the module it is used to break."""
    return ((max(n, 1) + 7) // 8) * 8


def poison_ids(
    stream: EdgeStream, n: int, positions, mode: str = "past_n"
) -> tuple[EdgeStream, InjectedFault]:
    """Plant out-of-range vertex ids at the given stream positions.

    ``mode``: ``"past_n"`` (id = n, the first row XLA silently clamps),
    ``"sacrificial"`` (id = the kernels' padding row ``n_pad``),
    ``"negative"`` (id = -1), ``"int_max"`` (id = 2**31 - 1).
    """
    values = {
        "past_n": n,
        "sacrificial": sacrificial_row(n),
        "negative": -1,
        "int_max": np.iinfo(np.int32).max,
    }
    if mode not in values:
        raise ValueError(f"unknown mode {mode!r}; use one of {sorted(values)}")
    pos = tuple(int(p) for p in positions)
    src = np.asarray(stream.src).copy()
    src[list(pos)] = np.int32(values[mode])
    return (
        _replace(stream, src=src),
        InjectedFault(
            kind="id_out_of_range",
            positions=pos,
            description=f"src id -> {values[mode]} ({mode}) at {list(pos)}",
        ),
    )


def poison_weights(
    stream: EdgeStream, positions, mode: str = "nan"
) -> tuple[EdgeStream, InjectedFault]:
    """Plant dirty weights: ``"nan"``, ``"posinf"``, ``"neginf"``, or
    ``"negative"`` (finite w = -1.5)."""
    values = {
        "nan": np.nan,
        "posinf": np.inf,
        "neginf": -np.inf,
        "negative": -1.5,
    }
    if mode not in values:
        raise ValueError(f"unknown mode {mode!r}; use one of {sorted(values)}")
    pos = tuple(int(p) for p in positions)
    w = np.asarray(stream.weight).copy()
    w[list(pos)] = np.float32(values[mode])
    kind = "negative_weight" if mode == "negative" else "nonfinite_weight"
    return (
        _replace(stream, weight=w),
        InjectedFault(
            kind=kind,
            positions=pos,
            description=f"weight -> {values[mode]} at {list(pos)}",
        ),
    )


# ---------------------------------------------------------------------------
# Result corruptions (for check_matching)
# ---------------------------------------------------------------------------


def corrupt_assigned(result: MatchingResult, position: int, value: int) -> MatchingResult:
    """Rewrite ``assigned[position] = value``, keeping the bit storage.

    Depending on ``value`` and the stream this manufactures an
    out-of-range substream, an ineligible/padding/self-loop record, or a
    duplicate per-substream match — the test picks the scenario."""
    assigned = np.asarray(result.assigned).copy()
    assigned[int(position)] = np.int32(value)
    return result.with_assigned(jnp.asarray(assigned))


def flip_matching_bit(result: MatchingResult, vertex: int, substream: int) -> MatchingResult:
    """Flip one matching bit ``mb[vertex, substream]`` in the result's own
    storage — XORing the byte of the packed bit-plane block when the
    result is packed, the bool entry when dense."""
    if result.is_packed:
        mbp = np.asarray(result.mb_packed).copy()
        mbp[int(vertex), int(substream) // 8] ^= np.uint8(1 << (int(substream) % 8))
        return MatchingResult(
            assigned=result.assigned, mb_packed=jnp.asarray(mbp), L=result.L
        )
    mb = np.asarray(result.mb).copy()
    mb[int(vertex), int(substream)] ^= True
    return MatchingResult(assigned=result.assigned, mb=jnp.asarray(mb))


def repacked(result: MatchingResult) -> MatchingResult:
    """The same result in packed storage (identity if already packed) —
    lets bit-plane corruption tests cover the packed path explicitly."""
    if result.is_packed:
        return result
    return MatchingResult(
        assigned=result.assigned, mb_packed=bitpack.pack_bits(result.mb), L=result.L
    )


# ---------------------------------------------------------------------------
# Schedule faults (for validate_schedule / the cascade)
# ---------------------------------------------------------------------------


def truncate_schedule(schedule):
    """Drop the last segment row of the slot layout — the shape of a stale
    schedule persisted for a shorter stream. ``validate_schedule`` must
    reject it (slot layout no longer agrees with the wave order)."""
    if schedule.num_segments == 0:
        raise ValueError("cannot truncate an empty schedule")
    return dataclasses.replace(schedule, slots=schedule.slots[:-1].copy())


def duplicate_order_entry(schedule):
    """Schedule the first edge twice (replacing the last scheduled edge,
    consistently in ``order`` AND the slot layout). When the two copies
    land in different waves this passes the coverage, slot-agreement and
    per-wave disjointness checks — only the order-is-a-permutation check
    rejects it."""
    if schedule.num_scheduled < 2:
        raise ValueError("need >= 2 scheduled edges to duplicate one")
    order = schedule.order.copy()
    slots = schedule.slots.copy()
    flat = slots.reshape(-1)
    pos = np.flatnonzero(flat >= 0)
    order[-1] = order[0]
    flat[pos[-1]] = order[0]
    return dataclasses.replace(
        schedule, order=order, slots=flat.reshape(slots.shape)
    )


def permute_schedule(schedule):
    """Reverse the wave-major order while keeping the slot layout — the
    shape of a schedule whose derived fields drifted after a stream
    permutation. ``validate_schedule`` must reject it (requires >= 2
    scheduled edges to be an actual corruption)."""
    if schedule.num_scheduled < 2:
        raise ValueError("permuting < 2 scheduled edges is a no-op")
    return dataclasses.replace(schedule, order=schedule.order[::-1].copy())


# ---------------------------------------------------------------------------
# Plan / compile fault forcing (for the fallback cascade)
# ---------------------------------------------------------------------------


class InjectedFailure(RuntimeError):
    """The exception :func:`failing` raises from patched internals."""


#: Patchable ops internals, by short target name. The *module attributes*
#: are patched (the wrappers look them up at call time), so the jit cache
#: cannot route around an injected failure.
_TARGETS = {
    "vmem_plan": "vmem_plan",
    "wave_plan": "wave_plan",
    "mega_plan": "mega_plan",
    "edges_device": "_substream_match_edges",
    "waves_device": "_waves_device",
    "mega_device": "_mega_device",
    "scan_oracle": "mwm_scan",
    "waves_xla": "mwm_waves",
}


@contextlib.contextmanager
def failing(*targets: str, exc_type=InjectedFailure):
    """Force the named ops/matching internals to raise inside the block.

    ``targets`` are keys of :data:`_TARGETS` — planners (``vmem_plan``,
    ``wave_plan``, ``mega_plan``), jitted device entries
    (``edges_device``, ``waves_device``, ``mega_device``), or the XLA
    fallbacks (``waves_xla``, ``scan_oracle``). Always restores the
    originals, even when the block raises."""
    from repro.core import matching as _matching
    from repro.kernels.substream_match import ops as _ops

    unknown = [t for t in targets if t not in _TARGETS]
    if unknown:
        raise ValueError(f"unknown targets {unknown}; use {sorted(_TARGETS)}")

    def _raiser(name):
        def _fail(*args, **kwargs):
            raise exc_type(f"injected failure in {name}")

        return _fail

    saved = []
    try:
        for t in targets:
            attr = _TARGETS[t]
            module = _matching if t in ("scan_oracle", "waves_xla") else _ops
            saved.append((module, attr, getattr(module, attr)))
            setattr(module, attr, _raiser(t))
        yield
    finally:
        for module, attr, fn in reversed(saved):
            setattr(module, attr, fn)


# --------------------------------------------------------------------------
# Execution faults (crashes, hangs, flakes) for the resumable executor —
# used by tests/test_resume.py and tests/test_fault_tolerance.py.


class SimulatedCrash(BaseException):
    """A process death, not an error: derives from ``BaseException`` so
    no ``except Exception`` in the pipeline (the fallback cascade, the
    ExecutionGuard) can absorb it — exactly like a real SIGKILL, the
    only recovery is to restart and resume from the latest snapshot."""


class TransientFlake(RuntimeError):
    """A retry-worthy failure (``transient = True``): the deterministic
    stand-in for a flaky interconnect or preempted device that the
    ExecutionGuard's retry/backoff path must survive."""

    transient = True


def kill_at_epoch(k: int):
    """An ``epoch_hook`` for ``match_epochs`` that crashes *after* epoch
    ``k`` completed and snapshotted — the canonical crash-matrix kill
    point (state for epochs ``<= k`` is durable, the rest is lost)."""

    def hook(epoch: int, state):
        if epoch == k:
            raise SimulatedCrash(f"killed after epoch {k}")

    return hook


def kill_mid_snapshot(manager, after_files: int = 1):
    """Make ``manager`` (a CheckpointManager or SnapshotManager) crash
    inside the commit: the tmp dir is fully written but the durable
    rename never happens, simulating power loss mid-commit. The next
    manager over the same directory must see only the previous step.
    Returns the patched underlying CheckpointManager."""
    mgr = getattr(manager, "manager", manager)

    def _crash(tmp, final):
        raise SimulatedCrash(f"killed mid-snapshot before rename of {tmp}")

    mgr._commit = _crash
    return mgr


class FakeClock:
    """Deterministic monotonic clock + sleep recorder for guard tests.

    ``clock()`` returns the current fake time; ``sleep(s)`` records
    ``s`` into ``sleeps`` and advances the clock. ``advance`` (set it
    before a call, or from inside the guarded fn via :func:`slow`)
    adds extra seconds to the *next* clock read — how tests make one
    attempt blow a deadline without real waiting."""

    def __init__(self):
        self.now = 0.0
        self.sleeps: list[float] = []
        self.advance = 0.0

    def __call__(self) -> float:
        self.now += self.advance
        self.advance = 0.0
        return self.now

    def sleep(self, seconds: float) -> None:
        self.sleeps.append(seconds)
        self.now += seconds


def slow(fn, clock: FakeClock, seconds: float):
    """Wrap ``fn`` so each call appears to take ``seconds`` on the fake
    clock (drives the deadline and straggler paths deterministically)."""

    def wrapped(*args, **kwargs):
        clock.advance = seconds
        return fn(*args, **kwargs)

    return wrapped


def flake(fn, times: int, exc_type=TransientFlake):
    """Fail the first ``times`` calls with ``exc_type``, then delegate —
    the fail-N-times-then-succeed shape the retry budget is sized for.
    The wrapper exposes ``calls`` for assertions."""
    state = {"calls": 0}

    def wrapped(*args, **kwargs):
        state["calls"] += 1
        if state["calls"] <= times:
            raise exc_type(
                f"injected flake {state['calls']}/{times} in "
                f"{getattr(fn, '__name__', fn)!r}"
            )
        return fn(*args, **kwargs)

    wrapped.calls = state
    return wrapped


@contextlib.contextmanager
def flaky(*targets: str, times: int = 1, exc_type=TransientFlake):
    """Like :func:`failing`, but fail-N-then-succeed: the named ops /
    matching internals raise ``exc_type`` on their first ``times``
    calls (counted per target) and then behave normally. Restores the
    originals on exit."""
    from repro.core import matching as _matching
    from repro.kernels.substream_match import ops as _ops

    unknown = [t for t in targets if t not in _TARGETS]
    if unknown:
        raise ValueError(f"unknown targets {unknown}; use {sorted(_TARGETS)}")

    saved = []
    try:
        for t in targets:
            attr = _TARGETS[t]
            module = _matching if t in ("scan_oracle", "waves_xla") else _ops
            saved.append((module, attr, getattr(module, attr)))
            setattr(module, attr, flake(getattr(module, attr), times, exc_type))
        yield
    finally:
        for module, attr, fn in reversed(saved):
            setattr(module, attr, fn)
