"""Minimal, dependency-free stand-in for the `hypothesis` API we use.

The real `hypothesis` is declared in requirements.txt and is what CI
installs; this shim only activates when it is missing (hermetic
containers), so the property-test suite still *runs* instead of dying at
collection with ModuleNotFoundError. It implements the small subset the
tests use — ``given``, ``settings``, ``assume`` and the strategies
``integers / floats / sampled_from / tuples / builds / data`` — with
deterministic seeding (derived from the test's qualified name and the
example index) but no shrinking and no failure database.

Activated by ``tests/conftest.py``::

    try:
        import hypothesis
    except ModuleNotFoundError:
        from repro.testing import minihyp
        minihyp.install()
"""
from __future__ import annotations

import sys
import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 50


class _Falsified(AssertionError):
    pass


class _Rejected(Exception):
    """Raised by assume(False); the example is skipped, not failed."""


def assume(condition) -> bool:
    if not condition:
        raise _Rejected()
    return True


class SearchStrategy:
    """A strategy = a sampling function rng -> value."""

    def __init__(self, sample):
        self._sample = sample

    def map(self, fn) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._sample(rng)))

    def filter(self, pred) -> "SearchStrategy":
        def sample(rng):
            for _ in range(100):
                v = self._sample(rng)
                if pred(v):
                    return v
            raise _Rejected()

        return SearchStrategy(sample)


def integers(min_value, max_value) -> SearchStrategy:
    return SearchStrategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value, **_kw) -> SearchStrategy:
    return SearchStrategy(lambda rng: float(rng.uniform(min_value, max_value)))


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def sampled_from(elements) -> SearchStrategy:
    seq = list(elements)
    return SearchStrategy(lambda rng: seq[int(rng.integers(len(seq)))])


def tuples(*strategies) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s._sample(rng) for s in strategies))


def lists(elements, min_size=0, max_size=10) -> SearchStrategy:
    def sample(rng):
        k = int(rng.integers(min_size, max_size + 1))
        return [elements._sample(rng) for _ in range(k)]

    return SearchStrategy(sample)


def builds(target, *strategies, **kw_strategies) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: target(
            *(s._sample(rng) for s in strategies),
            **{k: s._sample(rng) for k, s in kw_strategies.items()},
        )
    )


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


class DataObject:
    """Interactive draw handle, the result of drawing ``data()``."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy._sample(self._rng)


def data() -> SearchStrategy:
    return SearchStrategy(lambda rng: DataObject(rng))


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def decorate(fn):
        fn._minihyp_settings = dict(max_examples=max_examples)
        return fn

    return decorate


class HealthCheck:
    # accepted (and ignored) for API compatibility
    too_slow = data_too_large = filter_too_much = all = None


def given(*strategies, **kw_strategies):
    """Run the test over deterministic pseudo-random examples.

    The wrapper takes no parameters so pytest does not mistake the
    strategy-supplied arguments for fixtures (real hypothesis hides them
    the same way via its own integration).
    """

    def decorate(fn):
        conf = getattr(fn, "_minihyp_settings", None) or {}
        n_examples = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)
        base_seed = zlib.crc32(fn.__qualname__.encode())

        def wrapper():
            for i in range(n_examples):
                rng = np.random.default_rng((base_seed, i))
                try:
                    args = [s._sample(rng) for s in strategies]
                    kwargs = {k: s._sample(rng) for k, s in kw_strategies.items()}
                    fn(*args, **kwargs)
                except _Rejected:
                    continue
                except Exception as e:
                    raise _Falsified(
                        f"{fn.__qualname__} falsified on example {i} "
                        f"(minihyp seed ({base_seed}, {i})): {e!r}"
                    ) from e

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._minihyp_inner = fn
        return wrapper

    return decorate


def install() -> None:
    """Register this shim as ``hypothesis`` / ``hypothesis.strategies``."""
    if "hypothesis" in sys.modules:
        return
    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for name in (
        "SearchStrategy", "integers", "floats", "booleans", "sampled_from",
        "tuples", "lists", "builds", "just", "data",
    ):
        setattr(st, name, globals()[name])
    hyp.strategies = st
    hyp.given = given
    hyp.settings = settings
    hyp.assume = assume
    hyp.HealthCheck = HealthCheck
    hyp.__version__ = "0.0-minihyp"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st
