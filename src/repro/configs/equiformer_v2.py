"""equiformer-v2 [arXiv:2306.12059; unverified] — 12L d=128 l_max=6 m_max=2
8 heads, SO(2)-eSCN convolutions. See models/equiformer_v2.py for the
fidelity notes (exact azimuthal rotation, learned polar modulation)."""
import dataclasses

from repro.configs.registry import ArchSpec, GNN_SHAPES, register
from repro.models.equiformer_v2 import EqV2Config

CONFIG = EqV2Config(
    name="equiformer-v2", n_layers=12, d_hidden=128, l_max=6, m_max=2, n_heads=8
)
SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_hidden=16, l_max=2, d_in=8)

ARCH = register(
    ArchSpec(
        id="equiformer-v2",
        family="gnn",
        config=CONFIG,
        shapes=GNN_SHAPES,
        smoke_config=SMOKE,
        source="arXiv:2306.12059; unverified",
        gnn_model="equiformer_v2",
    )
)
