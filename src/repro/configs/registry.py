"""Architecture registry: --arch <id> resolves here.

Each arch module registers an :class:`ArchSpec` carrying its exact
published config, its shape set, sharding rules, and a reduced smoke
config. launch/steps.py turns (arch, shape) into a lowered step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

ARCHS: dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode | serve_scores | retrieval
    # LM
    seq_len: int = 0
    global_batch: int = 0
    # GNN
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    n_classes: int = 0
    batch_nodes: int = 0
    fanouts: tuple = ()
    batch_graphs: int = 0
    # recsys
    batch: int = 0
    n_candidates: int = 0


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    id: str
    family: str  # lm | gnn | recsys
    config: Any  # full published config
    shapes: dict[str, ShapeSpec]
    smoke_config: Any  # reduced config for CPU smoke tests
    source: str  # citation
    gnn_model: Optional[str] = None  # module name under repro.models
    notes: str = ""


def register(spec: ArchSpec) -> ArchSpec:
    ARCHS[spec.id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    import repro.configs  # noqa: F401 — populates ARCHS

    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def all_arch_ids() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(ARCHS)


# ---- shared shape sets ----------------------------------------------------

LM_SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", seq_len=4096, global_batch=256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", seq_len=32768, global_batch=32),
    "decode_32k": ShapeSpec("decode_32k", "decode", seq_len=32768, global_batch=128),
    # decode against a 512k cache is linear in cache length (see DESIGN.md
    # §5) — runnable for every LM arch via sequence-sharded KV.
    "long_500k": ShapeSpec("long_500k", "decode", seq_len=524288, global_batch=1),
}

GNN_SHAPES = {
    "full_graph_sm": ShapeSpec(
        "full_graph_sm", "train", n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7
    ),
    "minibatch_lg": ShapeSpec(
        "minibatch_lg", "train", n_nodes=232965, n_edges=114615892,
        batch_nodes=1024, fanouts=(15, 10), d_feat=602, n_classes=41,
    ),
    "ogb_products": ShapeSpec(
        "ogb_products", "train", n_nodes=2449029, n_edges=61859140,
        d_feat=100, n_classes=47,
    ),
    "molecule": ShapeSpec(
        "molecule", "train", n_nodes=30, n_edges=64, batch_graphs=128, d_feat=16,
    ),
}

RECSYS_SHAPES = {
    "train_batch": ShapeSpec("train_batch", "train", batch=65536),
    "serve_p99": ShapeSpec("serve_p99", "serve_scores", batch=512),
    "serve_bulk": ShapeSpec("serve_bulk", "serve_scores", batch=262144),
    "retrieval_cand": ShapeSpec(
        "retrieval_cand", "retrieval", batch=1, n_candidates=1_000_000
    ),
}


def sampled_subgraph_sizes(shape: ShapeSpec, pad: int = 8192):
    """Static padded (n_nodes, n_edges) of the fanout-sampled block graph."""
    assert shape.fanouts
    frontier = shape.batch_nodes
    tot_nodes = frontier
    tot_edges = 0
    for f in shape.fanouts:
        e = frontier * f
        tot_edges += e
        frontier = frontier + e  # worst case: all sampled nodes distinct
    tot_nodes = frontier
    rup = lambda x: (x + pad - 1) // pad * pad
    return rup(tot_nodes), rup(tot_edges)
