"""The paper's own workload: substream-centric MWM configs (§5).

Default parameters follow the evaluation: K=32, L=64, eps=0.1, Kronecker
n = 2^16..2^21 (m ~= 48 n), weights U[1, (1+eps)^(L-1)+1].
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class MatchingWorkload:
    name: str = "paper-matching"
    scale: int = 20  # Kronecker 2^scale vertices
    edge_factor: int = 48
    L: int = 64
    eps: float = 0.1
    K: int = 32  # blocking epoch rows
    seed: int = 0


CONFIG = MatchingWorkload()
SMOKE = dataclasses.replace(CONFIG, scale=8, edge_factor=8, L=16)
