"""meshgraphnet [arXiv:2010.03409; unverified] — 15L d=128, sum agg, MLP x2."""
import dataclasses

from repro.configs.registry import ArchSpec, GNN_SHAPES, register
from repro.models.meshgraphnet import MGNConfig

CONFIG = MGNConfig(name="meshgraphnet", n_layers=15, d_hidden=128)
SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_hidden=16, d_in=8)

ARCH = register(
    ArchSpec(
        id="meshgraphnet",
        family="gnn",
        config=CONFIG,
        shapes=GNN_SHAPES,
        smoke_config=SMOKE,
        source="arXiv:2010.03409; unverified",
        gnn_model="meshgraphnet",
    )
)
