"""internlm2-20b [arXiv:2403.17297; hf] — dense GQA transformer."""
import dataclasses

from repro.configs.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="internlm2-20b",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_head=128,
    d_ff=16384,
    vocab=92544,
    act="swiglu",
    rope_theta=1_000_000.0,
    expand_kv=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16, d_ff=128,
    vocab=512, attn_chunk=32, loss_chunk=32,
)

ARCH = register(
    ArchSpec(
        id="internlm2-20b",
        family="lm",
        config=CONFIG,
        shapes=LM_SHAPES,
        smoke_config=SMOKE,
        source="arXiv:2403.17297; hf",
    )
)
