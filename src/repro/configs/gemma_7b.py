"""gemma-7b [arXiv:2403.08295; hf] — GeGLU, head_dim=256, embed scaling."""
import dataclasses

from repro.configs.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="gemma-7b",
    n_layers=28,
    d_model=3072,
    n_heads=16,
    n_kv=16,
    d_head=256,
    d_ff=24576,
    vocab=256000,
    act="geglu",
    embed_scale=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16, d_ff=128,
    vocab=512, attn_chunk=32, loss_chunk=32,
)

ARCH = register(
    ArchSpec(
        id="gemma-7b",
        family="lm",
        config=CONFIG,
        shapes=LM_SHAPES,
        smoke_config=SMOKE,
        source="arXiv:2403.08295; hf",
    )
)
