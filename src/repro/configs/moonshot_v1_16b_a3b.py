"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B] — MoE 64e top-6.

Expert parallelism: 64 experts shard 4-per-device over the 16-wide model
axis (expert_sharding="ep").
"""
import dataclasses

from repro.configs.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="moonshot-v1-16b-a3b",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv=16,
    d_head=128,
    d_ff=1408,
    vocab=163840,
    act="swiglu",
    n_experts=64,
    top_k=6,
    expert_sharding="ep",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16, d_ff=96,
    vocab=512, n_experts=8, top_k=2, attn_chunk=32, loss_chunk=32,
)

ARCH = register(
    ArchSpec(
        id="moonshot-v1-16b-a3b",
        family="lm",
        config=CONFIG,
        shapes=LM_SHAPES,
        smoke_config=SMOKE,
        source="hf:moonshotai/Moonlight-16B-A3B",
    )
)
