"""grok-1-314b [hf:xai-org/grok-1; unverified] — MoE 8e top-2.

8 experts < 16-wide model axis: EPxTP folding (expert_fold=2) stores each
expert as two half-FFN "folded experts" so the folded expert dim (16)
shards the whole model axis — expert traffic moves activations
(all-to-all), never weights. Params are additionally FSDP-sharded
("embed" -> data): 314B bf16 cannot fit a 16-way shard alone.
"""
import dataclasses

from repro.configs.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv=8,
    d_head=128,
    d_ff=32768,
    vocab=131072,
    act="geglu",  # 3-matrix FFN matches the 314B total
    n_experts=8,
    top_k=2,
    expert_sharding="ep",
    expert_fold=2,  # 8 experts x 2 folds shard the 16-wide model axis
    logit_softcap=30.0,
    expand_kv=True,
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16, d_ff=128,
    vocab=512, n_experts=4, top_k=2, attn_chunk=32, loss_chunk=32,
)

ARCH = register(
    ArchSpec(
        id="grok-1-314b",
        family="lm",
        config=CONFIG,
        shapes=LM_SHAPES,
        smoke_config=SMOKE,
        source="hf:xai-org/grok-1; unverified",
    )
)
