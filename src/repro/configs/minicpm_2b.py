"""minicpm-2b [arXiv:2404.06395; hf] — llama-like dense, WSD schedule.

The WSD (warmup-stable-decay) schedule is this arch's contribution; the
trainer wires repro.optim.schedule.wsd_schedule as its default LR law.
36 heads do not divide the 16-wide model axis: attention runs batch-
parallel over (pod, data, model) while the MLP uses tensor parallelism —
see default_rules override below.
"""
import dataclasses

from repro.configs.registry import ArchSpec, LM_SHAPES, register
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="minicpm-2b",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv=36,
    d_head=64,
    d_ff=5760,
    vocab=122753,
    act="swiglu",
)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=72, n_heads=6, n_kv=6, d_head=12, d_ff=144,
    vocab=512, attn_chunk=32, loss_chunk=32,
)

ARCH = register(
    ArchSpec(
        id="minicpm-2b",
        family="lm",
        config=CONFIG,
        shapes=LM_SHAPES,
        smoke_config=SMOKE,
        source="arXiv:2404.06395; hf",
        notes="WSD schedule default; heads not divisible by model axis",
    )
)
