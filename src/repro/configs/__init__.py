"""Importing this package registers every architecture."""
from repro.configs import (  # noqa: F401
    bert4rec,
    egnn,
    equiformer_v2,
    gemma_7b,
    gin_tu,
    grok_1_314b,
    internlm2_20b,
    meshgraphnet,
    minicpm_2b,
    moonshot_v1_16b_a3b,
    paper_matching,
)
from repro.configs.registry import (  # noqa: F401
    ARCHS,
    ArchSpec,
    ShapeSpec,
    all_arch_ids,
    get_arch,
)
