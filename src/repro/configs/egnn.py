"""egnn [arXiv:2102.09844; paper] — E(n)-equivariant GNN, 4L d=64."""
import dataclasses

from repro.configs.registry import ArchSpec, GNN_SHAPES, register
from repro.models.egnn import EGNNConfig

CONFIG = EGNNConfig(name="egnn", n_layers=4, d_hidden=64)
SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_hidden=16, d_in=8)

ARCH = register(
    ArchSpec(
        id="egnn",
        family="gnn",
        config=CONFIG,
        shapes=GNN_SHAPES,
        smoke_config=SMOKE,
        source="arXiv:2102.09844; paper",
        gnn_model="egnn",
    )
)
