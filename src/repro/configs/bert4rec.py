"""bert4rec [arXiv:1904.06690; paper] — bidirectional sequence recommender."""
import dataclasses

from repro.configs.registry import ArchSpec, RECSYS_SHAPES, register
from repro.models.bert4rec import Bert4RecConfig

CONFIG = Bert4RecConfig(
    name="bert4rec", embed_dim=64, n_blocks=2, n_heads=2, seq_len=200,
    item_vocab=1_048_576,
)
SMOKE = dataclasses.replace(
    CONFIG, item_vocab=1024, seq_len=16, n_mask=4, n_negatives=64, n_context=4
)

ARCH = register(
    ArchSpec(
        id="bert4rec",
        family="recsys",
        config=CONFIG,
        shapes=RECSYS_SHAPES,
        smoke_config=SMOKE,
        source="arXiv:1904.06690; paper",
    )
)
