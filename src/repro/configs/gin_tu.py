"""gin-tu [arXiv:1810.00826; paper] — GIN, 5L d=64, sum agg, learnable eps."""
import dataclasses

from repro.configs.registry import ArchSpec, GNN_SHAPES, register
from repro.models.gin import GINConfig

CONFIG = GINConfig(name="gin-tu", n_layers=5, d_hidden=64)
SMOKE = dataclasses.replace(CONFIG, n_layers=2, d_hidden=16, d_in=8, n_classes=4)

ARCH = register(
    ArchSpec(
        id="gin-tu",
        family="gnn",
        config=CONFIG,
        shapes=GNN_SHAPES,
        smoke_config=SMOKE,
        source="arXiv:1810.00826; paper",
        gnn_model="gin",
    )
)
