"""Pallas TPU kernel: the paper's 8-stage edge-processor pipeline (§4.4).

TPU mapping of the FPGA design:

  FPGA                                   TPU (this kernel)
  ------------------------------------   --------------------------------
  BRAM-resident matching bits            VMEM scratch; packed layout
                                         mb[n_pad, ceil(L/8)] u8 (default)
                                         or unpacked mb[n_pad, L_pad] i8
  L-bit bit-parallel matching word       packed: 8 substreams per uint8
                                         lane word (the §4.3 BRAM word);
                                         unpacked: L on the lane axis
  1 edge / cycle pipeline                lax.fori_loop, 1 edge / iteration
  DRAM edge stream + prefetch            HBM->VMEM BlockSpec pipeline over
                                         edge blocks (double-buffered by
                                         the Pallas grid pipeline)
  epoch double-buffer of u-bits          whole bit-block stays resident;
                                         the lexicographic pre-sort keeps
                                         row touches epoch-local anyway

Stage map (Listing 2): Stage 1-3 = row loads (pl.load, dynamic slice),
Stage 4 = threshold compare (te), Stage 5 = matching update, Stage 6 =
row stores, Stage 7 = highest-set-bit, Stage 8 = assigned-index store.

Packed path details: eligibility is evaluated per *bit plane* — the
thresholds arrive as [8, W_pad] f32 where row j, word k holds substream
8k+j's threshold (+inf in padding slots), so `w >= thr` directly yields
the 8 bit planes of the L-bit eligibility word and an 8-way shift-OR
assembles the uint8 mask. The free test / matching update become single
bitwise ops on uint8 rows (te & ~mb[u] & ~mb[v]); Stage 7's highest set
bit is an 8-way shift-mask reduction over lane-index*8 + bit.

Capacity: the bit block must fit VMEM: n_pad * ceil(L/8) bytes packed
(8x the unpacked n_pad * L_pad budget of the int8 layout). Physical-TPU
note: uint8 tiles are (32, 128), so to realize the full win on hardware
when ceil(L/8) < 128 the row is folded vertex-major — G = 128 // W_pad
vertices share one 128-lane row (u selects row u // G, byte offset
(u % G) * W_pad). The interpret-mode kernel keeps the simple
[n_pad, W_pad] layout; ops.vmem_plan reports the logical packed bytes
either way. For
larger graphs the vertex set is partitioned across devices and the
parallel-rounds path (repro.core.rounds) stitches partitions together;
within a partition this kernel is the inner engine.

Grid: one program per edge block, sequential ("arbitrary") so the VMEM
scratch carries state across blocks — the stream order is preserved.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _split_refs(refs):
    """Unpack the trailing kernel refs: ``(assigned, mb_out, scratch)``
    plus an optional leading ``mb0`` input (the epoch executor's carried
    initial bit block — see ops.match_epochs). The wrappers only append
    the extra operand when an initial state is given, so the zero-state
    call graph (and its jit cache keys) is byte-for-byte unchanged."""
    if len(refs) == 4:
        return refs[0], refs[1], refs[2], refs[3]
    assigned_ref, mb_out_ref, mb = refs
    return None, assigned_ref, mb_out_ref, mb


def _kernel(edges_ref, w_ref, thr_ref, *refs, block_e: int):
    mb0_ref, assigned_ref, mb_out_ref, mb = _split_refs(refs)
    b = pl.program_id(0)
    nblocks = pl.num_programs(0)

    @pl.when(b == 0)
    def _init():
        mb[...] = jnp.zeros_like(mb) if mb0_ref is None else mb0_ref[...]

    L_pad = mb.shape[1]
    thr = thr_ref[0, :]  # [L_pad] f32; padding lanes hold +inf
    lane = jax.lax.broadcasted_iota(jnp.int32, (L_pad,), 0)

    def body(i, _):
        # Stage 1: unpack edge, compute row addresses
        u = edges_ref[i, 0]
        v = edges_ref[i, 1]
        w = w_ref[i, 0]
        # Stage 2-3: row loads (BRAM -> register in the paper)
        mbu = pl.load(mb, (pl.ds(u, 1), slice(None)))[0]  # [L_pad] i8
        mbv = pl.load(mb, (pl.ds(v, 1), slice(None)))[0]
        # Stage 4: eligibility te[i] = w >= (1+eps)^i  (+inf pads -> False)
        te = (w >= thr) & (u != v)
        # Stage 5: compute the matchings
        add = te & (mbu == 0) & (mbv == 0)
        addi = add.astype(jnp.int8)
        # Stage 6: write u/v bits back (v second: self-loop-safe, add=0 there)
        pl.store(mb, (pl.ds(u, 1), slice(None)), (mbu | addi)[None])
        mbv2 = pl.load(mb, (pl.ds(v, 1), slice(None)))[0]
        pl.store(mb, (pl.ds(v, 1), slice(None)), (mbv2 | addi)[None])
        # Stage 7: highest set bit
        idx = jnp.max(jnp.where(add, lane, -1))
        # Stage 8: emit assignment
        assigned_ref[i, 0] = idx
        return 0

    jax.lax.fori_loop(0, block_e, body, 0, unroll=False)

    @pl.when(b == nblocks - 1)
    def _flush():
        mb_out_ref[...] = mb[...]


def _kernel_packed(edges_ref, w_ref, thr_ref, *refs, block_e: int):
    """Packed bit-plane edge processor: mb rows are uint8 words of 8 bits."""
    mb0_ref, assigned_ref, mb_out_ref, mb = _split_refs(refs)
    b = pl.program_id(0)
    nblocks = pl.num_programs(0)

    @pl.when(b == 0)
    def _init():
        mb[...] = jnp.zeros_like(mb) if mb0_ref is None else mb0_ref[...]

    W_pad = mb.shape[1]
    thr = thr_ref[...]  # [8, W_pad] f32; +inf in padding slots
    lane = jax.lax.broadcasted_iota(jnp.int32, (W_pad,), 0)

    def body(i, _):
        # Stage 1: unpack edge, compute row addresses
        u = edges_ref[i, 0]
        v = edges_ref[i, 1]
        w = w_ref[i, 0]
        # Stage 2-3: row loads (BRAM -> register in the paper)
        mbu = pl.load(mb, (pl.ds(u, 1), slice(None)))[0]  # [W_pad] u8
        mbv = pl.load(mb, (pl.ds(v, 1), slice(None)))[0]
        # Stage 4: assemble the L-bit eligibility word from its 8 bit planes
        planes = w >= thr  # [8, W_pad] bool; plane j = substreams 8k+j
        te = jnp.zeros((W_pad,), jnp.uint8)
        for j in range(8):
            te |= planes[j].astype(jnp.uint8) << j
        te = jnp.where(u != v, te, jnp.uint8(0))  # self-loops never match
        # Stage 5: compute the matchings — one bitwise op per 8 substreams
        add = te & ~mbu & ~mbv
        # Stage 6: write u/v bits back (v second: self-loop-safe, add=0 there)
        pl.store(mb, (pl.ds(u, 1), slice(None)), (mbu | add)[None])
        mbv2 = pl.load(mb, (pl.ds(v, 1), slice(None)))[0]
        pl.store(mb, (pl.ds(v, 1), slice(None)), (mbv2 | add)[None])
        # Stage 7: highest set bit via shift-mask reduction over bit planes
        addi = add.astype(jnp.int32)
        idx = jnp.int32(-1)
        for j in range(8):
            hit = (addi >> j) & 1
            idx = jnp.maximum(idx, jnp.max(jnp.where(hit > 0, 8 * lane + j, -1)))
        # Stage 8: emit assignment
        assigned_ref[i, 0] = idx
        return 0

    jax.lax.fori_loop(0, block_e, body, 0, unroll=False)

    @pl.when(b == nblocks - 1)
    def _flush():
        mb_out_ref[...] = mb[...]


def _kernel_waves(
    edges_ref, w_ref, thr_ref, *refs,
    block_s: int, seg: int, n_out: int,
):
    """Segment-vectorized edge processor, unpacked int8 layout.

    One ``fori_loop`` iteration consumes one *segment* — ``seg``
    vertex-disjoint slots of the fill-packed schedule
    (`repro.graph.waves`): waves are packed back-to-back into fixed
    [seg]-slot rows, so the kernel never pays for a global max-wave
    width and its per-trip traffic is O(seg·width), proportional to the
    slots it actually processes, not to the graph. Row addressing is the
    gather/scatter form: both endpoint rows are gathered by row index,
    the eligibility/matching update runs as [seg, L_pad] tile ops, and
    the new bits are written back row-by-row in place — the former
    whole-block ``mball.at[u].add`` rematerialized (read + rewrote) the
    entire [n_rows, width] block once per wave, O(n·width) traffic that
    dominated near the VMEM capacity ceiling.

    Why in-place row writes are safe: real slots in a segment are
    vertex-disjoint (u-rows, v-rows all distinct), self-loops contribute
    ``add == 0`` and write their freshly-gathered row back unchanged,
    and padding slots are remapped by the caller to a *sacrificial* row
    at index ``n_out`` (outside the flushed block) so they can never
    race a real vertex-0 write — every duplicate row index in a scatter
    carries an identical value.

    Physical-TPU note: the row gather/scatter is expressed as
    array-indexed ref reads/writes, which interpret mode executes
    directly; on hardware the same addressing is a seg-step DMA row
    gather (the per-edge kernel's addressing, seg rows at a time) or a
    one-hot MXU matmul — the wave semantics are unchanged.
    """
    mb0_ref, assigned_ref, mb_out_ref, mb = _split_refs(refs)
    b = pl.program_id(0)
    nblocks = pl.num_programs(0)

    @pl.when(b == 0)
    def _init():
        mb[...] = jnp.zeros_like(mb) if mb0_ref is None else mb0_ref[...]

    L_pad = mb.shape[1]
    thr = thr_ref[0, :]  # [L_pad] f32; padding lanes hold +inf
    lane = jax.lax.broadcasted_iota(jnp.int32, (seg, L_pad), 1)

    def body(i, _):
        # Stage 1: load one segment of seg slots
        ed = pl.load(edges_ref, (pl.ds(i * seg, seg), slice(None)))  # [seg, 2]
        u = ed[:, 0]
        v = ed[:, 1]
        w = pl.load(w_ref, (pl.ds(i * seg, seg), slice(None)))[:, 0]  # [seg]
        # Stage 2-3: row-addressed gather of both endpoint rows
        mbu = mb[u, :]  # [seg, L_pad] i8
        mbv = mb[v, :]
        # Stage 4: eligibility for the whole segment at once
        te = (w[:, None] >= thr[None, :]) & (u != v)[:, None]
        # Stage 5: the matching update, one [seg, L_pad] tile op
        add = te & (mbu == 0) & (mbv == 0)
        addi = add.astype(jnp.int8)
        # Stage 6: in-place row scatter of the new bits
        mb[u, :] = mbu | addi
        mb[v, :] = mbv | addi
        # Stage 7: highest set bit, vectorized over the segment
        idx = jnp.max(jnp.where(add, lane, -1), axis=1)  # [seg]
        # Stage 8: emit one segment of assignments
        pl.store(assigned_ref, (pl.ds(i * seg, seg), slice(None)), idx[:, None])
        return 0

    jax.lax.fori_loop(0, block_s, body, 0, unroll=False)

    @pl.when(b == nblocks - 1)
    def _flush():
        mb_out_ref[...] = mb[0:n_out, :]


def _kernel_waves_packed(
    edges_ref, w_ref, thr_ref, *refs,
    block_s: int, seg: int, n_out: int,
):
    """Segment-vectorized edge processor, packed uint8 bit-plane layout.

    Same segment semantics as :func:`_kernel_waves`; the eligibility
    word is assembled per bit plane ([seg, 8, W_pad] compare, 8-way
    shift-OR) and the free test / matching update are single bitwise ops
    on the whole [seg, W_pad] uint8 tile before the in-place row
    scatter.
    """
    mb0_ref, assigned_ref, mb_out_ref, mb = _split_refs(refs)
    b = pl.program_id(0)
    nblocks = pl.num_programs(0)

    @pl.when(b == 0)
    def _init():
        mb[...] = jnp.zeros_like(mb) if mb0_ref is None else mb0_ref[...]

    W_pad = mb.shape[1]
    thr = thr_ref[...]  # [8, W_pad] f32; +inf in padding slots
    shift = jax.lax.broadcasted_iota(jnp.uint8, (1, 8, 1), 1)
    # substream index of bit j in word k: 8k + j, as one [1, W_pad, 8] map
    bit_of = (
        8 * jax.lax.broadcasted_iota(jnp.int32, (1, W_pad, 8), 1)
        + jax.lax.broadcasted_iota(jnp.int32, (1, W_pad, 8), 2)
    )

    def body(i, _):
        # Stage 1: load one segment of seg slots
        ed = pl.load(edges_ref, (pl.ds(i * seg, seg), slice(None)))  # [seg, 2]
        u = ed[:, 0]
        v = ed[:, 1]
        w = pl.load(w_ref, (pl.ds(i * seg, seg), slice(None)))[:, 0]  # [seg]
        # Stage 2-3: row-addressed gather of both endpoint rows
        mbu = mb[u, :]  # [seg, W_pad] u8
        mbv = mb[v, :]
        # Stage 4: assemble the L-bit eligibility words from bit planes —
        # plane bits are disjoint, so the shift-OR is a plain sum
        planes = w[:, None, None] >= thr[None, :, :]  # [seg, 8, W_pad]
        te = (planes.astype(jnp.uint8) << shift).sum(axis=1).astype(jnp.uint8)
        te = jnp.where((u != v)[:, None], te, jnp.uint8(0))
        # Stage 5: matching update — one bitwise op per 8 substreams
        add = te & ~mbu & ~mbv
        # Stage 6: in-place row scatter of the new bits
        mb[u, :] = mbu | add
        mb[v, :] = mbv | add
        # Stage 7: highest set bit over the unpacked [seg, W_pad, 8] view
        hit = (add[:, :, None] >> shift.reshape(1, 1, 8)) & 1
        idx = jnp.max(jnp.where(hit > 0, bit_of, -1), axis=(1, 2))  # [seg]
        # Stage 8: emit one segment of assignments
        pl.store(assigned_ref, (pl.ds(i * seg, seg), slice(None)), idx[:, None])
        return 0

    jax.lax.fori_loop(0, block_s, body, 0, unroll=False)

    @pl.when(b == nblocks - 1)
    def _flush():
        mb_out_ref[...] = mb[0:n_out, :]


def substream_match_pallas(
    edges: jax.Array,  # int32 [m_pad, 2]
    weights: jax.Array,  # f32/bf16 [m_pad, 1]; <= 0 marks padding edges
    thresholds: jax.Array,  # f32 [1, L_pad]; +inf in padding lanes
    n_pad: int,
    block_e: int = 1024,
    interpret: bool = True,
    mb_init: jax.Array | None = None,  # int8 [n_pad, L_pad] carried-in bits
):
    """Raw pallas_call wrapper, unpacked int8 layout (legacy fallback).

    See ops.substream_match for the typed API and the packed default.
    ``mb_init`` seeds the resident bit block instead of zeros (the epoch
    executor's carried state); ``None`` keeps the zero-init fast path.
    """
    m_pad = edges.shape[0]
    assert m_pad % block_e == 0, (m_pad, block_e)
    L_pad = thresholds.shape[1]
    nblocks = m_pad // block_e
    grid = (nblocks,)

    in_specs = [
        pl.BlockSpec((block_e, 2), lambda b: (b, 0)),  # edge block (pipelined)
        pl.BlockSpec((block_e, 1), lambda b: (b, 0)),  # weight block
        pl.BlockSpec((1, L_pad), lambda b: (0, 0)),  # thresholds (resident)
    ]
    operands = [edges, weights.astype(jnp.float32), thresholds]
    if mb_init is not None:
        assert mb_init.shape == (n_pad, L_pad), (mb_init.shape, n_pad, L_pad)
        in_specs.append(pl.BlockSpec((n_pad, L_pad), lambda b: (0, 0)))
        operands.append(mb_init.astype(jnp.int8))

    kernel = functools.partial(_kernel, block_e=block_e)
    assigned, mb = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_e, 1), lambda b: (b, 0)),
            pl.BlockSpec((n_pad, L_pad), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, L_pad), jnp.int8),
        ],
        scratch_shapes=[pltpu.VMEM((n_pad, L_pad), jnp.int8)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
    )(*operands)
    return assigned[:, 0], mb


def substream_match_pallas_packed(
    edges: jax.Array,  # int32 [m_pad, 2]
    weights: jax.Array,  # f32/bf16 [m_pad, 1]; <= 0 marks padding edges
    thresholds: jax.Array,  # f32 [8, W_pad]; thr[j, k] = substream 8k+j, +inf pads
    n_pad: int,
    block_e: int = 1024,
    interpret: bool = True,
    mb_init: jax.Array | None = None,  # uint8 [n_pad, W_pad] carried-in bits
):
    """Raw pallas_call wrapper, packed uint8 bit-plane layout (default path).

    Returns (assigned int32 [m_pad], mb_packed uint8 [n_pad, W_pad]).
    ``mb_init`` seeds the resident bit block instead of zeros (the epoch
    executor's carried state); ``None`` keeps the zero-init fast path.
    """
    m_pad = edges.shape[0]
    assert m_pad % block_e == 0, (m_pad, block_e)
    assert thresholds.shape[0] == 8, thresholds.shape
    W_pad = thresholds.shape[1]
    nblocks = m_pad // block_e
    grid = (nblocks,)

    in_specs = [
        pl.BlockSpec((block_e, 2), lambda b: (b, 0)),  # edge block (pipelined)
        pl.BlockSpec((block_e, 1), lambda b: (b, 0)),  # weight block
        pl.BlockSpec((8, W_pad), lambda b: (0, 0)),  # bit-plane thresholds
    ]
    operands = [edges, weights.astype(jnp.float32), thresholds]
    if mb_init is not None:
        assert mb_init.shape == (n_pad, W_pad), (mb_init.shape, n_pad, W_pad)
        in_specs.append(pl.BlockSpec((n_pad, W_pad), lambda b: (0, 0)))
        operands.append(mb_init.astype(jnp.uint8))

    kernel = functools.partial(_kernel_packed, block_e=block_e)
    assigned, mb = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block_e, 1), lambda b: (b, 0)),
            pl.BlockSpec((n_pad, W_pad), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, W_pad), jnp.uint8),
        ],
        scratch_shapes=[pltpu.VMEM((n_pad, W_pad), jnp.uint8)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
    )(*operands)
    return assigned[:, 0], mb


#: Extra scratch rows past ``n_pad``: row ``n_pad`` is the sacrificial
#: row every padding slot is remapped to (so its no-op writes can never
#: duplicate a real vertex row inside one scatter); the band is 8 rows
#: to keep the scratch row count a multiple of 8.
SACRIFICIAL_ROWS = 8


def _prefix_te_table(width: int) -> jax.Array:
    """[8 * width + 1, width] uint8: row c = the packed L-bit prefix mask
    with the lowest ``c`` bits set (bit j of word k = substream 8k+j).

    Substream thresholds are non-decreasing ((1+eps)^i), so the Stage-4
    eligibility word of an edge is always a *prefix*: te = all substreams
    whose threshold <= w. That reduces the per-edge threshold test to a
    count (how many thresholds pass) plus this table lookup — one fused
    [block]-wide compare/sum per grid program instead of a bit-plane
    assembly per tile. Built from iotas so it can live inside a Pallas
    kernel (captured array constants are rejected); a handful of one-time
    ops per grid program.
    """
    c = jax.lax.broadcasted_iota(jnp.int32, (8 * width + 1, width), 0)
    k = jax.lax.broadcasted_iota(jnp.int32, (8 * width + 1, width), 1)
    nbits = jnp.clip(c - 8 * k, 0, 8)
    return ((1 << nbits) - 1).astype(jnp.uint8)


def _high_bit_table() -> jax.Array:
    """[256] int32: highest set bit of a uint8 (floor log2), with a
    sentinel low enough that an all-zero eligibility row still reduces
    to < -1 after the word offsets (8k <= 8*width) are added. Uses the
    f32-exponent trick (exact for integers < 2^24) so it builds from an
    iota inside the kernel."""
    i = jax.lax.broadcasted_iota(jnp.int32, (256,), 0)
    e = (jax.lax.bitcast_convert_type(i.astype(jnp.float32), jnp.int32) >> 23) - 127
    return jnp.where(i > 0, e, -1024)


def _kernel_waves_mega(
    seg_offsets_ref, uv_ref, w_ref, thr_ref, *refs,
    tiles_per_block: int, bslots: int, seg_block: int, n_out: int,
):
    """Grid-pipelined segment megakernel, unpacked int8 layout.

    Same tile semantics and carry structure as
    :func:`_kernel_waves_mega_packed` (see its docstring for the
    pipeline story); the eligibility mask is the plain lane-prefix
    compare ``lane < cnt`` and the matching state is one int8 byte per
    substream bit.
    """
    mb0_ref, assigned_ref, mb_out_ref, mb = _split_refs(refs)
    b = pl.program_id(0)
    nblocks = pl.num_programs(0)

    @pl.when(b == 0)
    def _init():
        mb[...] = jnp.zeros_like(mb) if mb0_ref is None else mb0_ref[...]

    L_pad = mb.shape[1]
    block = tiles_per_block * bslots
    lane = jax.lax.broadcasted_iota(jnp.int32, (bslots, L_pad), 1)
    total_tiles = seg_offsets_ref[seg_offsets_ref.shape[0] - 1] // seg_block
    tiles_here = jnp.clip(total_tiles - b * tiles_per_block, 0, tiles_per_block)

    # Stage 4 for the whole program at once: thresholds are sorted, so
    # eligibility is the lane prefix below the per-slot pass count
    w_all = w_ref[...][:, 0]  # [block]
    cnt = jnp.sum(
        (w_all[:, None] >= thr_ref[0, :][None, :]), axis=1, dtype=jnp.int32
    )
    te_all = (
        jax.lax.broadcasted_iota(jnp.int32, (block, L_pad), 1) < cnt[:, None]
    ).astype(jnp.int8)

    def body(t, carry):
        mbv, asg = carry
        # Stage 1: one fused load of the tile's 2*bslots row addresses
        uv = pl.load(uv_ref, (pl.ds(t * 2 * bslots, 2 * bslots), slice(None)))[:, 0]
        te = jax.lax.dynamic_slice(te_all, (t * bslots, 0), (bslots, L_pad))
        # Stage 2-3: one fused gather of all endpoint rows
        rows = mbv[uv]  # [2 * bslots, L_pad] i8
        mbu = rows[:bslots]
        mbw = rows[bslots:]
        # Stage 5: the matching update, one [bslots, L_pad] tile op
        add = te & (1 - (mbu | mbw))
        # Stage 6: functional row scatter into the carried bit block —
        # duplicate uv rows (sacrificial padding) carry identical values,
        # so .at[].set is deterministic here
        mbv = mbv.at[uv].set(rows | jnp.concatenate([add, add]))
        # Stage 7: highest set bit, vectorized over the tile
        idx = jnp.max(jnp.where(add > 0, lane, -1), axis=1)  # [bslots]
        # Stage 8: emit the tile's assignments into the carried block
        asg = jax.lax.dynamic_update_slice(asg, idx, (t * bslots,))
        return mbv, asg

    mbf, asgf = jax.lax.fori_loop(
        0, tiles_here, body, (mb[...], jnp.full((block,), -1, jnp.int32))
    )
    mb[...] = mbf
    assigned_ref[...] = asgf[:, None]

    @pl.when(b == nblocks - 1)
    def _flush():
        mb_out_ref[...] = mb[0:n_out, :]


def _kernel_waves_mega_packed(
    seg_offsets_ref, uv_ref, w_ref, thr_ref, *refs,
    tiles_per_block: int, bslots: int, seg_block: int, n_out: int,
):
    """Grid-pipelined segment megakernel, packed uint8 bit-plane layout.

    The §4.4 pipeline, re-drawn at tile granularity. One *tile* is
    ``seg_block`` consecutive segment rows of the block-aligned layout
    (`repro.graph.waves.block_aligned_layout`) — ``bslots = seg_block *
    SEG`` slots that are guaranteed vertex-disjoint because no tile
    straddles a wave boundary. Three pipeline levels:

    * **grid** — each program consumes ``tiles_per_block`` tiles; the
      Pallas grid pipeline double-buffers the HBM->VMEM copy of the next
      program's slot-stream block behind the current program's compute
      (the paper's DRAM prefetcher);
    * **program** — Stage 4 runs once per program as a fused
      [block]-wide threshold count + prefix-table lookup (thresholds are
      sorted, so eligibility words are prefixes — see
      :func:`_prefix_te_table`), saturating the VPU at any L;
    * **tile loop** — the bit block AND the assigned block are carried
      as *values* through ``fori_loop`` (gather/compute/scatter as pure
      array ops, ref I/O only at the program boundary), so one trip
      costs one fused [2*bslots]-row gather, a handful of [bslots,
      W_pad] tile ops, and one fused scatter — no per-tile ref traffic,
      which dominates the discharged interpret-mode execution.

    The caller pre-remaps padding *and self-loop* slots to the
    sacrificial row with w = 0, so the kernel needs no per-tile
    ``u != v`` masking. The scalar-prefetched ``seg_offsets`` bound the
    loop: grid padding beyond the layout's real tile count is skipped
    entirely (its assigned slots stay -1), not processed-and-discarded.
    """
    mb0_ref, assigned_ref, mb_out_ref, mb = _split_refs(refs)
    b = pl.program_id(0)
    nblocks = pl.num_programs(0)

    @pl.when(b == 0)
    def _init():
        mb[...] = jnp.zeros_like(mb) if mb0_ref is None else mb0_ref[...]

    W_pad = mb.shape[1]
    block = tiles_per_block * bslots
    te_table = _prefix_te_table(W_pad)
    high_bit = _high_bit_table()
    word_off = 8 * jax.lax.broadcasted_iota(jnp.int32, (1, W_pad), 1)
    total_tiles = seg_offsets_ref[seg_offsets_ref.shape[0] - 1] // seg_block
    tiles_here = jnp.clip(total_tiles - b * tiles_per_block, 0, tiles_per_block)

    # Stage 4 for the whole program at once: count passing thresholds
    # per slot, then look the packed prefix word up in the table
    w_all = w_ref[...][:, 0]  # [block]
    cnt = jnp.sum(
        (w_all[:, None] >= thr_ref[0, :][None, :]), axis=1, dtype=jnp.int32
    )
    te_all = te_table[cnt]  # [block, W_pad] u8

    def body(t, carry):
        mbv, asg = carry
        # Stage 1: one fused load of the tile's 2*bslots row addresses
        uv = pl.load(uv_ref, (pl.ds(t * 2 * bslots, 2 * bslots), slice(None)))[:, 0]
        te = jax.lax.dynamic_slice(te_all, (t * bslots, 0), (bslots, W_pad))
        # Stage 2-3: one fused gather of all endpoint rows
        rows = mbv[uv]  # [2 * bslots, W_pad] u8
        mbu = rows[:bslots]
        mbw = rows[bslots:]
        # Stage 5: matching update — one bitwise op per 8 substreams
        add = te & ~(mbu | mbw)
        # Stage 6: functional row scatter into the carried bit block
        mbv = mbv.at[uv].set(rows | jnp.concatenate([add, add]))
        # Stage 7: highest set bit via the log2 table, one word at a time
        idx = jnp.maximum(
            jnp.max(high_bit[add.astype(jnp.int32)] + word_off, axis=1), -1
        )
        # Stage 8: emit the tile's assignments into the carried block
        asg = jax.lax.dynamic_update_slice(asg, idx, (t * bslots,))
        return mbv, asg

    mbf, asgf = jax.lax.fori_loop(
        0, tiles_here, body, (mb[...], jnp.full((block,), -1, jnp.int32))
    )
    mb[...] = mbf
    assigned_ref[...] = asgf[:, None]

    @pl.when(b == nblocks - 1)
    def _flush():
        mb_out_ref[...] = mb[0:n_out, :]


def substream_match_pallas_mega(
    uv: jax.Array,  # int32 [2 * total, 1], per-tile column-major (u's then v's)
    weights: jax.Array,  # f32 [total, 1]; padding/self-loop slots are 0
    thresholds: jax.Array,  # f32 [1, nbits] sorted flat, +inf in padding slots
    seg_offsets: jax.Array,  # int32 [num_waves + 1], block-aligned
    n_pad: int,
    seg: int,
    seg_block: int,
    tiles_per_block: int,
    interpret: bool = True,
    packed: bool = True,
    mb_init: jax.Array | None = None,  # [n_pad + SACRIFICIAL_ROWS, width]
):
    """Raw pallas_call wrapper for the grid-pipelined megakernel.

    The slot stream is the *block-aligned* layout
    (`repro.graph.waves.block_aligned_layout`), grid-padded to a
    ``tiles_per_block`` tile multiple (``total`` slots). ``uv`` is laid
    out per tile as all ``bslots`` u-rows then all ``bslots`` v-rows, so
    one contiguous load yields the tile's full gather index vector.
    Padding AND self-loop slots MUST be pre-remapped to ``u = v = n_pad``
    (the sacrificial row) with ``w = 0`` — the kernel has no in-loop
    self-loop test. ``thresholds`` is the *flat sorted* [1, nbits]
    threshold vector (nbits = 8 * W_pad packed, L_pad unpacked; +inf
    pads): eligibility is prefix-structured, see :func:`_prefix_te_table`.
    ``seg_offsets`` rides as scalar prefetch; its last entry bounds the
    tile loop. Returns (assigned int32 [total] — -1 on every padding
    slot — and mb as for the waves wrapper). ``mb_init`` seeds the
    resident bit block instead of zeros — shaped like the scratch
    (``n_pad + SACRIFICIAL_ROWS`` rows; the sacrificial band must be
    zero, though the kernel never reads it as a real vertex).
    """
    total = weights.shape[0]
    bslots = seg_block * seg
    block = tiles_per_block * bslots
    assert total % block == 0, (total, tiles_per_block, seg_block, seg)
    assert uv.shape[0] == 2 * total, (uv.shape, total)
    nblocks = total // block
    nbits = thresholds.shape[1]
    n_rows = n_pad + SACRIFICIAL_ROWS
    if packed:
        width = nbits // 8
        kernel_fn, dtype = _kernel_waves_mega_packed, jnp.uint8
    else:
        width = nbits
        kernel_fn, dtype = _kernel_waves_mega, jnp.int8

    kernel = functools.partial(
        kernel_fn,
        tiles_per_block=tiles_per_block,
        bslots=bslots,
        seg_block=seg_block,
        n_out=n_pad,
    )
    in_specs = [
        pl.BlockSpec((2 * block, 1), lambda b, offs: (b, 0)),  # uv stream
        pl.BlockSpec((block, 1), lambda b, offs: (b, 0)),  # weights
        pl.BlockSpec((1, nbits), lambda b, offs: (0, 0)),  # thresholds
    ]
    operands = [seg_offsets, uv, weights.astype(jnp.float32), thresholds]
    if mb_init is not None:
        assert mb_init.shape == (n_rows, width), (mb_init.shape, n_rows, width)
        in_specs.append(pl.BlockSpec((n_rows, width), lambda b, offs: (0, 0)))
        operands.append(mb_init.astype(dtype))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block, 1), lambda b, offs: (b, 0)),
            pl.BlockSpec((n_pad, width), lambda b, offs: (0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((n_rows, width), dtype)],
    )
    assigned, mb = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((total, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, width), dtype),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
    )(*operands)
    return assigned[:, 0], mb


def substream_match_pallas_waves(
    edges: jax.Array,  # int32 [num_segments_pad * seg, 2], packed slot layout
    weights: jax.Array,  # f32 [num_segments_pad * seg, 1]; padding slots are 0
    thresholds: jax.Array,  # f32 [1, L_pad] unpacked / [8, W_pad] packed
    n_pad: int,
    seg: int,
    block_s: int,
    interpret: bool = True,
    packed: bool = True,
    mb_init: jax.Array | None = None,  # [n_pad + SACRIFICIAL_ROWS, width]
):
    """Raw pallas_call wrapper for the segment-vectorized kernels.

    ``edges``/``weights`` are the fill-packed *slot* stream:
    ``num_segments_pad`` segments of exactly ``seg`` slots each (see
    ``repro.graph.waves`` — waves packed back-to-back, each padded only
    to the next ``seg`` multiple), flattened row-major. Padding slots
    MUST encode ``u = v = n_pad`` (the sacrificial bit-block row) with
    ``w = 0``: the in-place row scatter requires duplicate row indices
    to carry identical values, which a padding alias of a real vertex
    row would break. The grid walks blocks of ``block_s`` segments;
    ``assigned`` comes back per slot (callers scatter it to stream
    positions via the schedule's slot map). Returns (assigned int32
    [num_segments_pad * seg], mb — uint8 [n_pad, W_pad] packed /
    int8 [n_pad, L_pad] unpacked; the sacrificial band is not flushed).
    ``mb_init`` seeds the resident bit block instead of zeros — shaped
    like the scratch (``n_pad + SACRIFICIAL_ROWS`` rows, sacrificial
    band zero).
    """
    total = edges.shape[0]
    block = block_s * seg
    assert total % block == 0, (total, block_s, seg)
    nblocks = total // block
    width = thresholds.shape[1]
    n_rows = n_pad + SACRIFICIAL_ROWS
    if packed:
        assert thresholds.shape[0] == 8, thresholds.shape
        kernel_fn, dtype = _kernel_waves_packed, jnp.uint8
    else:
        assert thresholds.shape[0] == 1, thresholds.shape
        kernel_fn, dtype = _kernel_waves, jnp.int8

    in_specs = [
        pl.BlockSpec((block, 2), lambda b: (b, 0)),  # segment block (pipelined)
        pl.BlockSpec((block, 1), lambda b: (b, 0)),  # weight block
        pl.BlockSpec(thresholds.shape, lambda b: (0, 0)),  # thresholds
    ]
    operands = [edges, weights.astype(jnp.float32), thresholds]
    if mb_init is not None:
        assert mb_init.shape == (n_rows, width), (mb_init.shape, n_rows, width)
        in_specs.append(pl.BlockSpec((n_rows, width), lambda b: (0, 0)))
        operands.append(mb_init.astype(dtype))

    kernel = functools.partial(kernel_fn, block_s=block_s, seg=seg, n_out=n_pad)
    assigned, mb = pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((block, 1), lambda b: (b, 0)),
            pl.BlockSpec((n_pad, width), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((total, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, width), dtype),
        ],
        scratch_shapes=[pltpu.VMEM((n_rows, width), dtype)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
    )(*operands)
    return assigned[:, 0], mb
