"""Pallas TPU kernel: the paper's 8-stage edge-processor pipeline (§4.4).

TPU mapping of the FPGA design:

  FPGA                                   TPU (this kernel)
  ------------------------------------   --------------------------------
  BRAM-resident matching bits            VMEM scratch  mb[n_pad, L_pad] i8
  L-bit bit-parallel matching word       one vector row, L on the lane axis
  1 edge / cycle pipeline                lax.fori_loop, 1 edge / iteration
  DRAM edge stream + prefetch            HBM->VMEM BlockSpec pipeline over
                                         edge blocks (double-buffered by
                                         the Pallas grid pipeline)
  epoch double-buffer of u-bits          whole bit-block stays resident;
                                         the lexicographic pre-sort keeps
                                         row touches epoch-local anyway

Stage map (Listing 2): Stage 1-3 = row loads (pl.load, dynamic slice),
Stage 4 = threshold compare (te), Stage 5 = matching update, Stage 6 =
row stores, Stage 7 = highest-set-bit, Stage 8 = assigned-index store.

Capacity: the bit block must fit VMEM: n_pad * L_pad bytes (int8).
For larger graphs the vertex set is partitioned across devices and the
parallel-rounds path (repro.core.rounds) stitches partitions together;
within a partition this kernel is the inner engine.

Grid: one program per edge block, sequential ("arbitrary") so the VMEM
scratch carries state across blocks — the stream order is preserved.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(edges_ref, w_ref, thr_ref, assigned_ref, mb_out_ref, mb, *, block_e: int):
    b = pl.program_id(0)
    nblocks = pl.num_programs(0)

    @pl.when(b == 0)
    def _init():
        mb[...] = jnp.zeros_like(mb)

    L_pad = mb.shape[1]
    thr = thr_ref[0, :]  # [L_pad] f32; padding lanes hold +inf
    lane = jax.lax.broadcasted_iota(jnp.int32, (L_pad,), 0)

    def body(i, _):
        # Stage 1: unpack edge, compute row addresses
        u = edges_ref[i, 0]
        v = edges_ref[i, 1]
        w = w_ref[i, 0]
        # Stage 2-3: row loads (BRAM -> register in the paper)
        mbu = pl.load(mb, (pl.ds(u, 1), slice(None)))[0]  # [L_pad] i8
        mbv = pl.load(mb, (pl.ds(v, 1), slice(None)))[0]
        # Stage 4: eligibility te[i] = w >= (1+eps)^i  (+inf pads -> False)
        te = (w >= thr) & (u != v)
        # Stage 5: compute the matchings
        add = te & (mbu == 0) & (mbv == 0)
        addi = add.astype(jnp.int8)
        # Stage 6: write u/v bits back (v second: self-loop-safe, add=0 there)
        pl.store(mb, (pl.ds(u, 1), slice(None)), (mbu | addi)[None])
        mbv2 = pl.load(mb, (pl.ds(v, 1), slice(None)))[0]
        pl.store(mb, (pl.ds(v, 1), slice(None)), (mbv2 | addi)[None])
        # Stage 7: highest set bit
        idx = jnp.max(jnp.where(add, lane, -1))
        # Stage 8: emit assignment
        assigned_ref[i, 0] = idx
        return 0

    jax.lax.fori_loop(0, block_e, body, 0, unroll=False)

    @pl.when(b == nblocks - 1)
    def _flush():
        mb_out_ref[...] = mb[...]


def substream_match_pallas(
    edges: jax.Array,  # int32 [m_pad, 2]
    weights: jax.Array,  # f32/bf16 [m_pad, 1]; <= 0 marks padding edges
    thresholds: jax.Array,  # f32 [1, L_pad]; +inf in padding lanes
    n_pad: int,
    block_e: int = 1024,
    interpret: bool = True,
):
    """Raw pallas_call wrapper. See ops.substream_match for the typed API."""
    m_pad = edges.shape[0]
    assert m_pad % block_e == 0, (m_pad, block_e)
    L_pad = thresholds.shape[1]
    nblocks = m_pad // block_e
    grid = (nblocks,)

    kernel = functools.partial(_kernel, block_e=block_e)
    assigned, mb = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_e, 2), lambda b: (b, 0)),  # edge block (pipelined)
            pl.BlockSpec((block_e, 1), lambda b: (b, 0)),  # weight block
            pl.BlockSpec((1, L_pad), lambda b: (0, 0)),  # thresholds (resident)
        ],
        out_specs=[
            pl.BlockSpec((block_e, 1), lambda b: (b, 0)),
            pl.BlockSpec((n_pad, L_pad), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_pad, L_pad), jnp.int8),
        ],
        scratch_shapes=[pltpu.VMEM((n_pad, L_pad), jnp.int8)],
        interpret=interpret,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",),
        ),
    )(edges, weights.astype(jnp.float32), thresholds)
    return assigned[:, 0], mb
