from repro.kernels.substream_match.ops import substream_match  # noqa: F401
