from repro.kernels.substream_match.ops import (  # noqa: F401
    match_epochs,
    substream_match,
)
