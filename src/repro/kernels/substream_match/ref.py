"""Pure-jnp oracle for the substream_match Pallas kernel.

Semantics = Listing 1 Part 1 over the edge order given (the caller is
responsible for pre-sorting into the blocked lexicographic order — the
kernel processes edges exactly in the order it receives them, like the
FPGA pipeline processes the merged stream).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def substream_match_ref(
    src: jax.Array,  # int32 [m]
    dst: jax.Array,  # int32 [m]
    weight: jax.Array,  # float [m]; <= 0 encodes padding/invalid
    thresholds: jax.Array,  # float32 [L]
    n: int,
    mb0: jax.Array | None = None,  # int8/bool [n, L] carried-in bits
):
    """Returns (assigned int32 [m], mb int8 [n, L]). ``mb0`` seeds the
    matching bits (the epoch executor's carried state); default zeros."""
    L = thresholds.shape[0]

    def step(mb, e):
        u, v, w = e
        u = u.astype(jnp.int32)
        v = v.astype(jnp.int32)
        te = (w.astype(jnp.float32) >= thresholds) & (u != v)
        mbu = mb[u]
        mbv = mb[v]
        add = te & (mbu == 0) & (mbv == 0)
        addi = add.astype(jnp.int8)
        mb = mb.at[u].set(mbu | addi)
        mb = mb.at[v].set(mb[v] | addi)
        idx = jnp.where(
            add, jax.lax.broadcasted_iota(jnp.int32, add.shape, 0), -1
        ).max()
        return mb, idx

    init = (
        jnp.zeros((n, L), jnp.int8) if mb0 is None else mb0.astype(jnp.int8)
    )
    mb, assigned = jax.lax.scan(step, init, (src, dst, weight))
    return assigned, mb


def substream_match_ref_packed(
    src: jax.Array,  # int32 [m]
    dst: jax.Array,  # int32 [m]
    weight: jax.Array,  # float [m]; <= 0 encodes padding/invalid
    thresholds: jax.Array,  # float32 [L]
    n: int,
    mb0: jax.Array | None = None,  # uint8 [n, ceil(L/8)] carried-in bits
):
    """Packed-word oracle: the same scan, but the state is the uint8
    bit-plane word of :mod:`repro.core.bitpack` and every per-edge update is
    a bitwise op on ceil(L/8) words — an independent re-derivation of the
    packed Pallas kernel's bit logic (not a pack() of the dense oracle).

    Returns (assigned int32 [m], mb_packed uint8 [n, ceil(L/8)]).
    """
    from repro.core import bitpack

    L = thresholds.shape[0]
    W = bitpack.packed_width(L)
    nbits = W * bitpack.BITS
    thr_flat = jnp.full((nbits,), jnp.inf, jnp.float32).at[:L].set(thresholds)
    thr_bits = thr_flat.reshape(W, bitpack.BITS)  # [W, 8]; [k, j] = substream 8k+j
    shifts = jnp.arange(bitpack.BITS, dtype=jnp.uint8)
    bitval = (jnp.uint8(1) << shifts).astype(jnp.uint8)
    bitidx = 8 * jnp.arange(W, dtype=jnp.int32)[:, None] + jnp.arange(
        bitpack.BITS, dtype=jnp.int32
    )

    def step(mb, e):
        u, v, w = e
        u = u.astype(jnp.int32)
        v = v.astype(jnp.int32)
        planes = (w.astype(jnp.float32) >= thr_bits) & (u != v)  # [W, 8]
        te = (planes.astype(jnp.uint8) * bitval).sum(-1).astype(jnp.uint8)  # [W]
        add = te & ~mb[u] & ~mb[v]
        mb = mb.at[u].set(mb[u] | add)
        mb = mb.at[v].set(mb[v] | add)
        hit = ((add[:, None] >> shifts) & jnp.uint8(1)) > 0  # [W, 8]
        idx = jnp.where(hit, bitidx, -1).max()
        return mb, idx

    init = (
        jnp.zeros((n, W), jnp.uint8) if mb0 is None else mb0.astype(jnp.uint8)
    )
    mb, assigned = jax.lax.scan(step, init, (src, dst, weight))
    return assigned, mb
