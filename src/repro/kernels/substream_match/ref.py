"""Pure-jnp oracle for the substream_match Pallas kernel.

Semantics = Listing 1 Part 1 over the edge order given (the caller is
responsible for pre-sorting into the blocked lexicographic order — the
kernel processes edges exactly in the order it receives them, like the
FPGA pipeline processes the merged stream).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def substream_match_ref(
    src: jax.Array,  # int32 [m]
    dst: jax.Array,  # int32 [m]
    weight: jax.Array,  # float [m]; <= 0 encodes padding/invalid
    thresholds: jax.Array,  # float32 [L]
    n: int,
):
    """Returns (assigned int32 [m], mb int8 [n, L])."""
    L = thresholds.shape[0]

    def step(mb, e):
        u, v, w = e
        u = u.astype(jnp.int32)
        v = v.astype(jnp.int32)
        te = (w.astype(jnp.float32) >= thresholds) & (u != v)
        mbu = mb[u]
        mbv = mb[v]
        add = te & (mbu == 0) & (mbv == 0)
        addi = add.astype(jnp.int8)
        mb = mb.at[u].set(mbu | addi)
        mb = mb.at[v].set(mb[v] | addi)
        idx = jnp.where(
            add, jax.lax.broadcasted_iota(jnp.int32, add.shape, 0), -1
        ).max()
        return mb, idx

    mb0 = jnp.zeros((n, L), jnp.int8)
    mb, assigned = jax.lax.scan(step, mb0, (src, dst, weight))
    return assigned, mb
