"""Typed / padded entry point for the substream_match Pallas kernel.

VMEM accounting (the §4.3 "storage" analysis, TPU edition)
----------------------------------------------------------
A TPU v5e core has ~16 MiB of VMEM (``VMEM_PER_CORE``). Of that we
reserve ``VMEM_BIT_BUDGET`` (12 MiB) for the resident matching-bit
block and leave the remainder for the edge-stream double buffers that
the Pallas grid pipeline allocates (edges + weights in, assigned out).

Two matching-bit layouts are supported (see :mod:`repro.core.bitpack`):

* ``packed`` (default) — ``mb[n_pad, ceil(L/8)]`` uint8, bit ``j`` of
  word ``k`` = substream ``8k + j``. One byte stores 8 substreams, the
  direct analogue of the paper's L-bit BRAM word; capacity per core is
  8x the unpacked layout (≥ 8x more vertices at any L; 16x at L = 64,
  where the unpacked layout also pays lane padding 64 -> 128).
* ``unpacked`` — ``mb[n_pad, L_pad]`` int8, one byte per substream bit.
  Legacy fallback, selected with ``SubstreamConfig(mb_layout="unpacked")``
  or ``substream_match(..., packed=False)``.

:func:`vmem_plan` is the single source of truth for the block geometry:
it reports the padded shape and byte footprint of the bit block for
either layout and auto-picks ``block_e`` — the edge-block length — from
the VMEM budget the bit block leaves free. Both the kernel wrapper and
the capacity benchmarks (`benchmarks/table6_memory.py`) consume it.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import bitpack
from repro.core.types import EdgeStream, MatchingResult, SubstreamConfig
from repro.kernels.substream_match import kernel as _kernel

VMEM_PER_CORE = 16 * 2**20  # usable VMEM on a v5e core
VMEM_BIT_BUDGET = 12 * 2**20  # bytes reserved for the matching-bit block
_EDGE_BYTES = 2 * (2 * 4 + 4 + 4)  # (src,dst) i32 + w f32 + assigned i32, x2 buffers


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class VmemPlan:
    """Geometry + budget of the VMEM matching-bit block.

    ``width`` is the padded per-vertex row in bytes (``L_pad`` int8 lanes
    unpacked; ``ceil(L/8)`` rounded up to 8 uint8 words packed), ``words``
    the logical (unpadded) row length, ``nbytes = n_pad * width`` the block
    footprint, and ``block_e`` the auto-picked edge-block length (see
    :func:`vmem_plan` for the selection rule).
    """

    n_pad: int
    width: int
    words: int
    nbytes: int
    block_e: int
    packed: bool

    @property
    def bytes_per_vertex(self) -> int:
        return self.width


def vmem_plan(
    n: int,
    L: int,
    packed: bool = True,
    block_e: int | None = None,
    m: int | None = None,
) -> VmemPlan:
    """Plan the VMEM bit block for ``n`` vertices and ``L`` substreams.

    The auto ``block_e`` is min over three constraints (power of two,
    floor 128): the VMEM the bit block leaves free at ``_EDGE_BYTES``
    per edge, an 8192 cap bounding per-program pipeline latency, and —
    when the stream length ``m`` is given — the smallest power of two
    covering ``m``, so short streams are not padded to a huge block.
    Since the bit block is capped at 12 of 16 MiB, at least 4 MiB stays
    free and the VMEM constraint only binds below ~256 KiB of headroom;
    in practice the 8192 cap or ``m`` decides.
    """
    n_pad = _round_up(max(n, 1), 8)
    if packed:
        words = bitpack.packed_width(max(L, 1))
        width = _round_up(words, 8)
    else:
        words = max(L, 1)
        width = _round_up(words, 128)
    nbytes = n_pad * width
    if block_e is None:
        free = max(VMEM_PER_CORE - min(nbytes, VMEM_BIT_BUDGET), 2**20)
        block_e = 1 << ((free // _EDGE_BYTES).bit_length() - 1)
        block_e = min(block_e, 8192)
        if m is not None:
            block_e = min(block_e, 1 << max(m - 1, 1).bit_length())
        block_e = max(128, block_e)
    return VmemPlan(
        n_pad=n_pad, width=width, words=words, nbytes=nbytes,
        block_e=block_e, packed=packed,
    )


def max_vertices(L: int, packed: bool = True, budget: int = VMEM_BIT_BUDGET) -> int:
    """Largest vertex count whose bit block fits ``budget`` bytes."""
    width = vmem_plan(1, L, packed=packed).width
    return (budget // width) // 8 * 8


def resolve_interpret(interpret: bool | None) -> bool:
    """``None`` = auto: interpret everywhere except on a real TPU backend.

    Explicit True/False always wins (debugging a kernel in interpret mode
    on TPU, or forcing compilation in tests, stays possible). The flip
    is no longer silent: :func:`substream_match` emits one structured
    ``substream_match.backend`` telemetry event (backend, interpret,
    engine) per call, so bench JSON records which backend actually ran.
    """
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


#: Bytes one slot occupies in the kernel's HBM slot stream: (src, dst)
#: int32 in, weight f32 in, assigned int32 out — a single buffer (the
#: double-buffering of ``_EDGE_BYTES`` is a VMEM *capacity* cost, not
#: extra HBM traffic).
SLOT_STREAM_BYTES = 16


def traffic_bytes(total_slots: int, live_slots: int, width: int) -> int:
    """Modeled per-call HBM traffic of the row-addressed kernels.

    The slot stream in + assigned out (``SLOT_STREAM_BYTES`` per padded
    slot) plus the bit-block row traffic: two row gathers and two row
    scatters of ``width`` bytes per live slot. This is the bytes-moved
    term :func:`repro.launch.roofline.substream_achieved` divides by —
    exact integers from the plan accounting, so telemetry counters
    derived from it are reproducible bit-exactly.
    """
    return total_slots * SLOT_STREAM_BYTES + live_slots * 4 * width


def plan_counters(plan: VmemPlan) -> dict:
    """The plan-accounting counter set (``plan.*``) for telemetry.

    Bit-exact copies of the :func:`vmem_plan` / :func:`wave_plan` /
    :func:`mega_plan` fields — tests and the bench gate compare these
    ``==`` against a recomputed plan, so no derived or rounded values.
    """
    out = {
        "plan.n_pad": int(plan.n_pad),
        "plan.width": int(plan.width),
        "plan.words": int(plan.words),
        "plan.bit_block_bytes": int(plan.nbytes),
        "plan.block_e": int(plan.block_e),
        "plan.packed": int(plan.packed),
    }
    if isinstance(plan, WavePlan):
        out.update(
            {
                "plan.seg": int(plan.seg),
                "plan.num_waves": int(plan.num_waves),
                "plan.num_segments": int(plan.num_segments),
                "plan.block_s": int(plan.block_s),
                "plan.gather_bytes": int(plan.gather_bytes),
                "plan.fill": float(plan.fill),
                "plan.seg_block": int(plan.seg_block),
                "plan.num_tiles": int(plan.num_tiles),
                "plan.tiles_per_block": int(plan.tiles_per_block),
                "plan.tile_bytes": int(plan.tile_bytes),
            }
        )
    return out


@dataclasses.dataclass(frozen=True)
class WavePlan(VmemPlan):
    """VmemPlan plus the segment-pipeline geometry.

    ``seg`` is the fixed slot count per segment tile (the schedule's
    fill-packed row width), ``num_waves``/``num_segments`` the
    schedule's true wave count and packed row count, ``block_s`` how
    many segments one grid program consumes (so ``block_e = block_s *
    seg`` slots), ``gather_bytes`` the VMEM the per-trip [seg, width]
    gather/compute tiles add on top of the resident bit block —
    accounted against ``VMEM_PER_CORE`` by :func:`wave_plan` — and
    ``fill`` the schedule's slot fill (fraction of slots holding a real
    edge).
    """

    seg: int
    num_waves: int
    num_segments: int
    block_s: int
    gather_bytes: int
    fill: float
    #: Mega-path geometry (zero on plain wave plans): ``seg_block``
    #: segments per tile, ``num_tiles`` real tiles in the block-aligned
    #: layout, ``tiles_per_block`` tiles per grid program, and
    #: ``tile_bytes`` the single-buffer working set of one in-flight
    #: tile — ``gather_bytes`` on a mega plan is ``2 * tile_bytes``
    #: (double-buffered: the gather of tile k+1 overlaps the
    #: compute/scatter of tile k).
    seg_block: int = 0
    num_tiles: int = 0
    tiles_per_block: int = 0
    tile_bytes: int = 0


def wave_plan(
    n: int,
    L: int,
    schedule,
    packed: bool = True,
    block_s: int | None = None,
) -> WavePlan:
    """Plan VMEM for the segment-vectorized kernel over ``schedule``.

    On top of the bit block (see :func:`vmem_plan`; plus one 8-row
    sacrificial band for padding slots) the kernel keeps per-segment
    tiles resident while a trip is in flight: the two gathered
    endpoint-row tiles, the eligibility/add tiles, the [seg, 8, width]
    bool bit-plane compare, and the [seg]-sized edge/weight/assigned
    vectors — ~12 tiles of ``seg * width`` bytes between them. The tile
    size is the *segment*, so the footprint no longer scales with the
    largest wave: gather bytes are per trip, proportional to ``seg``.
    The auto ``block_s`` targets ~512 slots per grid program (the
    measured interpret-mode sweet spot; a short latency envelope on
    hardware), never exceeds the schedule's segment count, and shrinks
    until the double-buffered slot-stream blocks fit the VMEM the bit
    block and gather tiles leave free. Errors name the knob that must
    change.
    """
    seg = int(schedule.width)
    num_waves = int(schedule.num_waves)
    num_segments = int(schedule.num_segments)
    base = vmem_plan(n, L, packed=packed, block_e=1)
    gather_bytes = 12 * seg * base.width + 24 * seg
    free = VMEM_PER_CORE - min(base.nbytes, VMEM_BIT_BUDGET)
    # blame the segment tiles only when they are the culprit: a bit block
    # over VMEM_BIT_BUDGET is the caller's (vertex-partitioning) problem
    # and is reported by substream_match's budget check instead
    if gather_bytes > free:
        raise ValueError(
            f"segment tiles ({gather_bytes} B at seg={seg}) + bit block "
            f"({base.nbytes} B) exceed VMEM; rebuild the schedule with a "
            f"smaller seg (repro.graph.waves.wave_schedule(seg=...))"
        )
    stream_free = free - gather_bytes
    if block_s is None:
        # ~512 slots per grid program: measured sweet spot of the
        # interpret-mode pipeline (smaller per-program input copies) and
        # a short enough latency envelope on hardware
        block_s = max(1, min(512 // seg, 256))
        block_s = min(block_s, max(num_segments, 1))
        while block_s > 1 and block_s * seg * _EDGE_BYTES > stream_free:
            block_s //= 2
    if block_s * seg * _EDGE_BYTES > stream_free:
        raise ValueError(
            f"slot-stream blocks ({block_s * seg * _EDGE_BYTES} B at "
            f"block_s={block_s}, seg={seg}) exceed the VMEM left by the "
            f"bit block and segment tiles ({stream_free} B); lower "
            f"block_s (ops.wave_plan) or seg "
            f"(repro.graph.waves.wave_schedule(seg=...))"
        )
    return WavePlan(
        n_pad=base.n_pad,
        width=base.width,
        words=base.words,
        nbytes=base.nbytes,
        block_e=block_s * seg,
        packed=packed,
        seg=seg,
        num_waves=num_waves,
        num_segments=num_segments,
        block_s=block_s,
        gather_bytes=gather_bytes,
        fill=float(schedule.fill),
    )


#: Default segments per megakernel tile. Measured sweet spot of the
#: tile-count / slot-inflation trade (block-aligned padding grows with
#: ``seg_block`` while sequential tile trips shrink as ``1/seg_block``);
#: 2 wins at every benchmarked scale.
MEGA_SEG_BLOCK = 2


def mega_plan(
    n: int,
    L: int,
    layout,
    packed: bool = True,
    tiles_per_block: int | None = None,
) -> WavePlan:
    """Plan VMEM for the grid-pipelined megakernel over ``layout``
    (a :class:`repro.graph.waves.BlockAlignedLayout`).

    On top of the resident bit block (plus the sacrificial band) the
    megakernel keeps one tile's working set in flight — the
    [2*bslots, width] gathered rows, eligibility/add tiles, the
    [bslots, 8, width] bit-plane compare, and the index/weight/assigned
    vectors, ~14 ``width``-wide arrays of ``bslots = seg_block * seg``
    rows plus 24 B/slot of vectors = ``tile_bytes``. The plan charges
    **2x** that (``gather_bytes``): the grid pipeline prefetches the
    next block's stream while the current tile computes, so two tile
    buffers coexist. The auto ``tiles_per_block`` is the measured
    interpret-mode sweet spot (64 tiles per program for short layouts,
    stepping to 128/256 as the tile count grows), clamped to the layout
    and halved until the double-buffered slot-stream blocks fit the
    VMEM left over.
    """
    seg = int(layout.width)
    seg_block = int(layout.seg_block)
    bslots = seg_block * seg
    num_tiles = int(layout.num_tiles)
    base = vmem_plan(n, L, packed=packed, block_e=1)
    tile_bytes = 14 * bslots * base.width + 24 * bslots
    gather_bytes = 2 * tile_bytes  # double-buffered tile working sets
    free = VMEM_PER_CORE - min(base.nbytes, VMEM_BIT_BUDGET)
    if gather_bytes > free:
        raise ValueError(
            f"double-buffered mega tiles ({gather_bytes} B at "
            f"seg_block={seg_block}, seg={seg}) + bit block ({base.nbytes} B) "
            f"exceed VMEM; rebuild the layout with a smaller seg_block "
            f"(repro.graph.waves.block_aligned_layout)"
        )
    stream_free = free - gather_bytes
    if tiles_per_block is None:
        # measured interpret-mode sweet spots: short layouts want small
        # per-program input copies, long ones amortize program overhead
        if num_tiles <= 1024:
            tiles_per_block = 64
        elif num_tiles <= 4096:
            tiles_per_block = 128
        else:
            tiles_per_block = 256
        tiles_per_block = max(1, min(tiles_per_block, num_tiles))
        while (
            tiles_per_block > 1
            and tiles_per_block * bslots * _EDGE_BYTES > stream_free
        ):
            tiles_per_block //= 2
    if tiles_per_block * bslots * _EDGE_BYTES > stream_free:
        raise ValueError(
            f"slot-stream blocks ({tiles_per_block * bslots * _EDGE_BYTES} B "
            f"at tiles_per_block={tiles_per_block}, seg_block={seg_block}, "
            f"seg={seg}) exceed the VMEM left by the bit block and tile "
            f"buffers ({stream_free} B); lower tiles_per_block "
            f"(ops.mega_plan) or seg_block"
        )
    return WavePlan(
        n_pad=base.n_pad,
        width=base.width,
        words=base.words,
        nbytes=base.nbytes,
        block_e=tiles_per_block * bslots,
        packed=packed,
        seg=seg,
        num_waves=int(layout.seg_offsets.shape[0] - 1),
        num_segments=int(layout.num_segments),
        block_s=tiles_per_block * seg_block,
        gather_bytes=gather_bytes,
        fill=float(layout.fill),
        seg_block=seg_block,
        num_tiles=num_tiles,
        tiles_per_block=tiles_per_block,
        tile_bytes=tile_bytes,
    )


def _resolve_packed(cfg: SubstreamConfig, packed: bool | None) -> bool:
    if packed is None:
        if cfg.mb_layout not in ("packed", "unpacked"):
            raise ValueError(f"unknown mb_layout {cfg.mb_layout!r}")
        packed = cfg.mb_layout != "unpacked"
    return packed


def _thresholds_padded(cfg: SubstreamConfig, width: int, packed: bool) -> jax.Array:
    """Kernel-shaped threshold array: [8, width] bit planes (packed,
    thr[j, k] = substream 8k+j) or [1, width] lanes (unpacked); +inf pads."""
    thr = cfg.thresholds()
    if packed:
        nbits = width * 8
        thr_flat = jnp.full((nbits,), jnp.inf, jnp.float32).at[: cfg.L].set(thr)
        return thr_flat.reshape(width, 8).T
    return jnp.full((1, width), jnp.inf, jnp.float32).at[0, : cfg.L].set(thr)


class FallbackExhaustedError(RuntimeError):
    """Every engine in the fallback cascade failed.

    ``attempts`` is the ordered ``(engine_label, exception)`` list, so a
    service log shows the whole degradation path in one line.
    """

    def __init__(self, attempts):
        self.attempts = tuple(attempts)
        lines = "; ".join(
            f"{label}: {type(err).__name__}: {err}" for label, err in self.attempts
        )
        super().__init__(f"all fallback engines failed ({lines})")


def _empty_result(stream: EdgeStream, cfg: SubstreamConfig, packed: bool):
    """Well-formed nothing-matched result (n == 0 vertex spaces)."""
    assigned = jnp.full((stream.num_edges,), -1, jnp.int32)
    if packed:
        words = bitpack.packed_width(max(cfg.L, 1))
        return MatchingResult(
            assigned=assigned,
            mb_packed=jnp.zeros((0, words), jnp.uint8),
            L=cfg.L,
        )
    return MatchingResult(assigned=assigned, mb=jnp.zeros((0, cfg.L), bool))


def _mb0_pad(mb0, n, words, rows, width, packed):
    """Pad a caller-format initial bit block (uint8 [n, words] packed /
    bool [n, L] dense) to the kernel scratch shape [rows, width]; the
    padding band (incl. the sacrificial rows) is zero — padding slots
    carry w = 0, so those bits are never set nor read."""
    dtype = jnp.uint8 if packed else jnp.int8
    return (
        jnp.zeros((rows, width), dtype).at[:n, :words].set(mb0.astype(dtype))
    )


def _mb0_dense(mb0, cfg: SubstreamConfig, packed: bool):
    """Caller-format initial bits as the dense bool [n, L] the XLA
    engines consume."""
    if mb0 is None:
        return None
    if packed:
        return bitpack.unpack_bits(jnp.asarray(mb0), cfg.L)
    return jnp.asarray(mb0).astype(bool)


def _repack(result: MatchingResult, packed: bool) -> MatchingResult:
    """Convert a dense XLA-fallback result to the storage the caller asked
    for, so cascade consumers see the same ``is_packed`` contract as the
    Pallas engines (`mb`/`assigned` are bit-identical either way)."""
    if packed and not result.is_packed:
        return MatchingResult(
            assigned=result.assigned,
            mb_packed=bitpack.pack_bits(result.mb),
            L=result.L,
        )
    return result


def _run_engine(
    engine: str,
    stream: EdgeStream,
    cfg: SubstreamConfig,
    *,
    block_e,
    interpret,
    packed,
    waves,
    max_width,
    seg_block,
    block_s,
    telemetry,
    mb0=None,
) -> MatchingResult:
    """Dispatch one concrete engine of the cascade. The XLA fallbacks are
    looked up through the module at call time (not from-imported), so the
    fault injector can force them to fail too. ``mb0`` (caller storage:
    uint8 [n, words] packed / bool [n, L] dense) seeds the matching bits;
    the XLA rungs take the dense view."""
    if engine == "mega":
        return _substream_match_mega(
            stream, cfg, interpret=interpret, packed=packed, waves=waves,
            max_width=max_width, seg_block=seg_block, telemetry=telemetry,
            mb0=mb0,
        )
    if engine == "waves":
        return _substream_match_waves(
            stream, cfg, interpret=interpret, packed=packed, waves=waves,
            max_width=max_width, block_s=block_s, telemetry=telemetry,
            mb0=mb0,
        )
    if engine == "edges":
        return _edges_entry(
            stream, cfg, block_e=block_e, interpret=interpret, packed=packed,
            telemetry=telemetry, mb0=mb0,
        )
    from repro.core import matching as _matching

    if engine == "waves_xla":
        return _repack(
            _matching.mwm_waves(
                stream, cfg, schedule=waves, max_width=max_width,
                telemetry=telemetry, mb0=_mb0_dense(mb0, cfg, packed),
            ),
            packed,
        )
    if engine == "scan":
        return _repack(
            _matching.mwm_scan(stream, cfg, mb0=_mb0_dense(mb0, cfg, packed)),
            packed,
        )
    if engine == "ref":
        from repro.kernels.substream_match import ref as _ref

        w = jnp.where(stream.valid, stream.weight.astype(jnp.float32), 0.0)
        thr = cfg.thresholds()
        init = None if mb0 is None else jnp.asarray(mb0)
        if packed:
            assigned, mb = _ref.substream_match_ref_packed(
                stream.src, stream.dst, w, thr, cfg.n, mb0=init
            )
            return MatchingResult(assigned=assigned, mb_packed=mb, L=cfg.L)
        assigned, mb = _ref.substream_match_ref(
            stream.src, stream.dst, w, thr, cfg.n, mb0=init
        )
        return MatchingResult(assigned=assigned, mb=mb.astype(bool))
    raise ValueError(f"unknown engine {engine!r}")


def _fallback_attempts(schedule: str, seg_block, block_s):
    """The ordered degradation ladder for ``on_plan_failure="fallback"``:
    shrink the failing engine's tile knob first (smaller VMEM working
    set), then step down mega -> waves -> waves_xla -> scan. Each entry
    is ``(engine, {knob overrides}, label)``."""
    shrink_waves = [("waves", {"block_s": block_s}, "waves")]
    if block_s != 1:
        shrink_waves.append(("waves", {"block_s": 1}, "waves[block_s=1]"))
    xla = [("waves_xla", {}, "waves_xla"), ("scan", {}, "scan")]
    if schedule == "mega":
        attempts = [("mega", {"seg_block": seg_block}, "mega")]
        if (MEGA_SEG_BLOCK if seg_block is None else seg_block) != 1:
            attempts.append(("mega", {"seg_block": 1}, "mega[seg_block=1]"))
        return attempts + shrink_waves + xla
    if schedule == "waves":
        return shrink_waves + xla
    return [("edges", {}, "edges")] + xla


def _substream_match_fallback(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    *,
    block_e,
    interpret,
    packed,
    schedule,
    waves,
    max_width,
    seg_block,
    block_s,
    telemetry,
    mb0=None,
) -> MatchingResult:
    """The fallback cascade resolver (``on_plan_failure="fallback"``).

    Runs the :func:`_fallback_attempts` ladder until an engine returns a
    result. Every failure is observable: a ``fallback`` instant event
    (from_engine, to_engine, reason) plus the ``fallback.count`` session
    counter, and each degraded attempt runs inside a ``fallback`` span.
    The per-call :class:`repro.obs.MatchTelemetry` record of the engine
    that finally succeeded carries ``fallback.count`` (0 on the clean
    path — the bench gate pins that). Validation and invariant errors
    are *not* absorbed: a bad stream fails every engine identically, so
    retrying would only mask the caller's bug.
    """
    from repro.core import guard as _guard

    attempts = _fallback_attempts(schedule, seg_block, block_s)
    failures = []
    for idx, (engine, overrides, label) in enumerate(attempts):
        kw = {"seg_block": seg_block, "block_s": block_s}
        kw.update(overrides)
        ncalls = len(telemetry.match_calls)
        span = (
            telemetry.span("fallback", engine=label, attempt=idx)
            if failures
            else obs.NULL_SPAN
        )
        try:
            with span:
                out = _run_engine(
                    engine, stream, cfg, block_e=block_e, interpret=interpret,
                    packed=packed, waves=waves, max_width=max_width,
                    seg_block=kw["seg_block"], block_s=kw["block_s"],
                    telemetry=telemetry, mb0=mb0,
                )
        except (_guard.StreamValidationError, _guard.MatchingInvariantError):
            raise
        except Exception as err:  # noqa: BLE001 — availability cascade
            failures.append((label, err))
            if telemetry.enabled:
                nxt = attempts[idx + 1][2] if idx + 1 < len(attempts) else None
                telemetry.event(
                    "fallback",
                    from_engine=label,
                    to_engine=nxt,
                    reason=f"{type(err).__name__}: {err}"[:500],
                )
                telemetry.counters.add("fallback.count")
            if idx + 1 == len(attempts):
                raise FallbackExhaustedError(failures) from err
            continue
        if telemetry.enabled and len(telemetry.match_calls) > ncalls:
            # stamp the degradation depth onto the per-call record of the
            # engine that actually produced the result (0 = clean path)
            telemetry.match_calls[-1].counters["fallback.count"] = len(failures)
        return out
    raise FallbackExhaustedError(failures)


def substream_match(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    block_e: int | None = None,
    interpret: bool | None = None,
    packed: bool | None = None,
    schedule: str = "edges",
    waves=None,
    max_width: int | None = None,
    seg_block: int | None = None,
    block_s: int | None = None,
    telemetry=obs.DISABLED,
    on_plan_failure: str = "raise",
    validate: str = "off",
    mb0: jax.Array | None = None,
) -> MatchingResult:
    """Run Part 1 on the given stream order via the Pallas kernel.

    ``mb0`` seeds the matching bits with carried-in state (the epoch
    executor's resume path; see :func:`match_epochs`) — uint8
    ``[n, ceil(L/8)]`` when ``packed``, bool ``[n, L]`` otherwise.
    ``None`` (the default) is the plain zero-state run and leaves every
    jit cache key and kernel call graph byte-identical to before.

    ``schedule`` picks the pipeline:

    * ``"edges"`` — the paper-faithful 1-edge-per-iteration processor;
    * ``"waves"`` — the wave-vectorized processor: the stream is first
      decomposed into vertex-disjoint waves (``repro.graph.waves``) on
      the host, then each wave updates the bit block as one [W, width]
      tile op. Bit-identical to ``"edges"`` (greedy matching is
      confluent over vertex-disjoint edges) with ``#waves`` instead of
      ``m`` inner-loop trips. Pass a precomputed ``waves`` schedule to
      amortize the decomposition across runs; ``max_width`` caps the
      wave width when building one here.
    * ``"mega"`` — the grid-pipelined megakernel: the wave schedule is
      re-padded block-aligned (every tile of ``seg_block`` segments is a
      subset of one wave, hence vertex-disjoint) and each trip processes
      one whole tile with the bit block carried functionally through the
      loop. Same bit-identical contract as ``"waves"``, ~``seg_block``x
      fewer sequential trips; ``seg_block=None`` takes
      :data:`MEGA_SEG_BLOCK`.

    ``packed=None`` follows ``cfg.mb_layout``; ``block_e=None`` takes the
    auto-picked value from :func:`vmem_plan` (edges schedule only).
    ``interpret=None`` = auto: interpret everywhere except on a real TPU
    backend (:func:`resolve_interpret`). The packed result carries
    ``mb_packed`` (uint8 bit planes) and unpacks to the bool ``mb`` view
    lazily; both layouts are bit-identical in ``assigned`` and ``mb``.

    ``telemetry`` (a :class:`repro.obs.Telemetry`; default: the no-op
    :data:`repro.obs.DISABLED`) records one ``substream_match.backend``
    event naming the backend that actually ran, stage spans
    (schedule/pack/layout/compile/execute), the plan/schedule counters,
    and a per-call :class:`repro.obs.MatchTelemetry` appended to
    ``telemetry.match_calls``.

    ``validate`` is the input-guard policy (``"off"`` default — zero
    overhead for trusted paths; ``"strict"`` raises on malformed
    streams, ``"sanitize"`` drops bad edges and reports via counters —
    see :func:`repro.core.guard.validate_stream`).

    ``on_plan_failure`` picks what happens when a plan exceeds VMEM or
    the Pallas path fails: ``"raise"`` (default, today's behavior)
    propagates; ``"fallback"`` degrades through the cascade — shrunk
    ``seg_block``/``block_s`` first, then mega -> waves -> ``waves_xla``
    -> the scan oracle — emitting ``fallback`` spans/events/counters so
    the degradation is observable, never silent. ``block_s`` caps the
    wave path's segments-per-program (``None`` = the plan's auto pick).

    With ``on_plan_failure="raise"``, raises if the bit block exceeds
    the VMEM budget — at that size the caller must vertex-partition
    (core.rounds) instead.
    """
    if validate != "off":
        from repro.core import guard as _guard

        stream, _ = _guard.validate_stream(
            stream, cfg.n, policy=validate, telemetry=telemetry
        )
    interpret = resolve_interpret(interpret)
    packed = _resolve_packed(cfg, packed)
    if schedule not in ("edges", "waves", "mega"):
        raise ValueError(f"unknown schedule {schedule!r}")
    if on_plan_failure not in ("raise", "fallback"):
        raise ValueError(
            f"unknown on_plan_failure {on_plan_failure!r}; "
            f"use 'raise' or 'fallback'"
        )
    if telemetry.enabled:
        telemetry.event(
            "substream_match.backend",
            engine=schedule,
            backend=jax.default_backend(),
            interpret=bool(interpret),
        )
    if cfg.n == 0:
        return _empty_result(stream, cfg, packed)
    if on_plan_failure == "fallback":
        return _substream_match_fallback(
            stream, cfg, block_e=block_e, interpret=interpret, packed=packed,
            schedule=schedule, waves=waves, max_width=max_width,
            seg_block=seg_block, block_s=block_s, telemetry=telemetry,
            mb0=mb0,
        )
    if schedule == "edges":
        return _edges_entry(
            stream, cfg, block_e=block_e, interpret=interpret, packed=packed,
            telemetry=telemetry, mb0=mb0,
        )
    if schedule == "waves":
        return _substream_match_waves(
            stream, cfg, interpret=interpret, packed=packed,
            waves=waves, max_width=max_width, block_s=block_s,
            telemetry=telemetry, mb0=mb0,
        )
    return _substream_match_mega(
        stream, cfg, interpret=interpret, packed=packed,
        waves=waves, max_width=max_width, seg_block=seg_block,
        telemetry=telemetry, mb0=mb0,
    )


def _edges_entry(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    block_e: int | None,
    interpret: bool,
    packed: bool,
    telemetry,
    mb0=None,
) -> MatchingResult:
    """Telemetry shell of the per-edge engine (the jitted body is
    :func:`_substream_match_edges`, unchanged). The edges path has no
    host scheduling, so schedule/pack/layout stages stay 0."""
    m = stream.num_edges
    rec = obs.recorder(
        telemetry, "pallas_edges", m, jax.default_backend(), interpret
    )
    if telemetry.enabled:
        plan = vmem_plan(cfg.n, cfg.L, packed=packed, block_e=block_e, m=m)
        m_pad = _round_up(max(m, 1), plan.block_e)
        rec.put_many(plan_counters(plan))
        rec.put("stream.num_edges", m)
        rec.put("traffic.hbm_bytes", traffic_bytes(m_pad, m, plan.width))
    key = (
        "edges", cfg.n, cfg.L, cfg.eps, packed, interpret, block_e, m,
        mb0 is not None,
    )
    with rec.device_stage(key):
        out = _substream_match_edges(
            stream, cfg, block_e=block_e, interpret=interpret, packed=packed,
            mb0=None if mb0 is None else jnp.asarray(mb0),
        )
        rec.block(out)
    rec.finish()
    return out


@partial(jax.jit, static_argnames=("cfg", "block_e", "interpret", "packed"))
def _substream_match_edges(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    block_e: int | None,
    interpret: bool,
    packed: bool,
    mb0: jax.Array | None = None,
) -> MatchingResult:
    plan = vmem_plan(
        cfg.n, cfg.L, packed=packed, block_e=block_e, m=stream.num_edges
    )
    if plan.nbytes > VMEM_BIT_BUDGET:
        raise ValueError(
            f"matching-bit block {plan.nbytes/2**20:.1f} MiB > VMEM budget; "
            f"use repro.core.rounds with vertex partitioning"
        )
    block_e = plan.block_e
    m = stream.num_edges
    # empty streams still run one block of no-op padding edges (u=v=0,
    # w=0) so the kernel's init/flush executes and mb comes back zeroed
    m_pad = _round_up(max(m, 1), block_e)
    pad = m_pad - m

    edges = jnp.stack([stream.src, stream.dst], axis=1).astype(jnp.int32)
    # invalid edges -> weight 0 (< every threshold, since thresholds >= 1)
    w = jnp.where(stream.valid, stream.weight.astype(jnp.float32), 0.0)
    if pad:
        edges = jnp.concatenate([edges, jnp.zeros((pad, 2), jnp.int32)])
        w = jnp.concatenate([w, jnp.zeros((pad,), jnp.float32)])
    thr_pad = _thresholds_padded(cfg, plan.width, packed)
    mb_init = (
        None
        if mb0 is None
        else _mb0_pad(mb0, cfg.n, plan.words, plan.n_pad, plan.width, packed)
    )

    if packed:
        assigned, mb = _kernel.substream_match_pallas_packed(
            edges, w[:, None], thr_pad, plan.n_pad,
            block_e=block_e, interpret=interpret, mb_init=mb_init,
        )
        return MatchingResult(
            assigned=assigned[:m],
            mb_packed=mb[: cfg.n, : plan.words],
            L=cfg.L,
        )

    assigned, mb = _kernel.substream_match_pallas(
        edges, w[:, None], thr_pad, plan.n_pad, block_e=block_e,
        interpret=interpret, mb_init=mb_init,
    )
    return MatchingResult(
        assigned=assigned[:m], mb=mb[: cfg.n, : cfg.L].astype(bool)
    )


@partial(
    jax.jit,
    static_argnames=(
        "cfg", "seg", "block_s", "n_pad", "width", "words", "interpret", "packed"
    ),
)
def _waves_device(
    edges, w, cfg, seg, block_s, n_pad, width, words, interpret, packed,
    mb0=None,
):
    """Jitted device half of the wave path: run the segment kernel over
    the host-prepped slot stream. ``edges``/``w`` are already
    grid-padded with padding slots remapped to the sacrificial row (see
    :func:`_substream_match_waves`, which also scatters the per-slot
    assignments back to stream positions — a plain numpy indexed store,
    since every stream position occupies exactly one slot). ``mb0``
    (caller storage) seeds the resident bit block; the sacrificial band
    pads with zeros."""
    thr_pad = _thresholds_padded(cfg, width, packed)
    rows = n_pad + _kernel.SACRIFICIAL_ROWS
    mb_init = (
        None
        if mb0 is None
        else _mb0_pad(mb0, cfg.n, words, rows, width, packed)
    )
    assigned_slots, mb = _kernel.substream_match_pallas_waves(
        edges, w, thr_pad, n_pad,
        seg=seg, block_s=block_s, interpret=interpret, packed=packed,
        mb_init=mb_init,
    )
    if packed:
        return assigned_slots, mb[: cfg.n, :words]
    return assigned_slots, mb[: cfg.n, : cfg.L].astype(bool)


def _substream_match_waves(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    interpret: bool,
    packed: bool,
    waves=None,
    max_width: int | None = None,
    block_s: int | None = None,
    telemetry=obs.DISABLED,
    mb0=None,
) -> MatchingResult:
    from repro.graph import waves as _waves

    rec = obs.recorder(
        telemetry, "pallas_waves", stream.num_edges,
        jax.default_backend(), interpret,
    )
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    if waves is None:
        # built in-call: the schedule's own stopwatch measurements are
        # the stage split (assign -> "schedule", layout -> "pack")
        waves = _waves.resolve_schedule(
            src, dst, valid, schedule=None, max_width=max_width,
            telemetry=telemetry,
        )
        rec.add_stage("schedule", waves.schedule_seconds)
        rec.add_stage("pack", waves.pack_seconds)
    else:
        with rec.stage("schedule"):  # precomputed: validation cost only
            waves = _waves.resolve_schedule(
                src, dst, valid, schedule=waves, max_width=max_width,
                telemetry=telemetry,
            )
    plan = wave_plan(cfg.n, cfg.L, waves, packed=packed, block_s=block_s)
    if plan.nbytes > VMEM_BIT_BUDGET:
        raise ValueError(
            f"matching-bit block {plan.nbytes/2**20:.1f} MiB > VMEM budget; "
            f"use repro.core.rounds with vertex partitioning"
        )
    with rec.stage("layout"):
        u, v, w, ok = _waves.slot_arrays(
            waves, src, dst, np.asarray(stream.weight), valid
        )
        # host-side slot prep (all vectorized numpy): remap padding slots to
        # the sacrificial bit-block row n_pad — the in-place row scatter
        # needs duplicate row indices to carry identical values, which a
        # padding alias of real vertex 0 would break — and pad the segment
        # count up to the grid block
        ns = u.shape[0]
        ns_pad = _round_up(max(ns, 1), plan.block_s)
        total = ns_pad * plan.seg
        sac = np.int32(plan.n_pad)
        edges = np.full((total, 2), sac, np.int32)
        wf = np.zeros((total, 1), np.float32)
        okf = ok.reshape(-1)
        edges[: ns * plan.seg, 0] = np.where(okf, u.reshape(-1), sac)
        edges[: ns * plan.seg, 1] = np.where(okf, v.reshape(-1), sac)
        wf[: ns * plan.seg, 0] = w.reshape(-1)
    if telemetry.enabled:
        rec.put_many(_waves.schedule_counters(waves))
        rec.put_many(plan_counters(plan))
        rec.put("stream.num_edges", stream.num_edges)
        rec.put(
            "traffic.hbm_bytes",
            traffic_bytes(total, waves.num_scheduled, plan.width),
        )
    key = (
        "waves", plan.seg, plan.block_s, plan.n_pad, plan.width, plan.words,
        interpret, packed, total, cfg.n, cfg.L, cfg.eps, mb0 is not None,
    )
    with rec.device_stage(key):
        assigned_slots, mb = _waves_device(
            jnp.asarray(edges),
            jnp.asarray(wf),
            cfg,
            plan.seg,
            plan.block_s,
            plan.n_pad,
            plan.width,
            plan.words,
            interpret,
            packed,
            mb0=None if mb0 is None else jnp.asarray(mb0),
        )
        rec.block((assigned_slots, mb))
    with rec.stage("layout"):
        # slot -> stream-position scatter on the host: each stream position
        # occupies exactly one slot, so this is a plain indexed store
        m = stream.num_edges
        flat = waves.slots.reshape(-1)
        live = flat >= 0
        assigned = np.full(m, -1, np.int32)
        assigned[flat[live]] = np.asarray(assigned_slots)[: flat.size][live]
        assigned = jnp.asarray(assigned)
    rec.finish()
    if packed:
        return MatchingResult(assigned=assigned, mb_packed=mb, L=cfg.L)
    return MatchingResult(assigned=assigned, mb=mb)


def _thresholds_flat(cfg: SubstreamConfig, nbits: int) -> jax.Array:
    """Megakernel-shaped thresholds: [1, nbits] sorted flat, +inf pads.

    The mega kernels exploit the prefix structure of sorted thresholds
    (see ``kernel._prefix_te_table``), so they take the flat ascending
    vector instead of the per-bit-plane [8, W_pad] layout.
    """
    thr = cfg.thresholds()
    return jnp.full((1, nbits), jnp.inf, jnp.float32).at[0, : cfg.L].set(thr)


@partial(
    jax.jit,
    static_argnames=(
        "cfg", "seg", "seg_block", "tiles_per_block", "n_pad", "width",
        "words", "interpret", "packed",
    ),
)
def _mega_device(
    seg_offsets, uv, w, cfg, seg, seg_block, tiles_per_block,
    n_pad, width, words, interpret, packed, mb0=None,
):
    """Jitted device half of the mega path. Thresholds are built inside
    the jit (a dozen jnp dispatches otherwise dominate small graphs);
    ``seg_offsets`` rides along as the scalar prefetch so the kernel can
    bound its tile loop at the layout's real tile count. ``mb0`` (caller
    storage) seeds the resident bit block; the sacrificial band pads
    with zeros."""
    thr_flat = _thresholds_flat(cfg, width * 8 if packed else width)
    rows = n_pad + _kernel.SACRIFICIAL_ROWS
    mb_init = (
        None
        if mb0 is None
        else _mb0_pad(mb0, cfg.n, words, rows, width, packed)
    )
    assigned_slots, mb = _kernel.substream_match_pallas_mega(
        uv, w, thr_flat, seg_offsets, n_pad,
        seg=seg, seg_block=seg_block, tiles_per_block=tiles_per_block,
        interpret=interpret, packed=packed, mb_init=mb_init,
    )
    if packed:
        return assigned_slots, mb[: cfg.n, :words]
    return assigned_slots, mb[: cfg.n, : cfg.L].astype(bool)


def _substream_match_mega(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    interpret: bool,
    packed: bool,
    waves=None,
    max_width: int | None = None,
    seg_block: int | None = None,
    telemetry=obs.DISABLED,
    mb0=None,
) -> MatchingResult:
    from repro.graph import waves as _waves

    if seg_block is None:
        seg_block = MEGA_SEG_BLOCK
    rec = obs.recorder(
        telemetry, "pallas_mega", stream.num_edges,
        jax.default_backend(), interpret,
    )
    src = np.asarray(stream.src)
    dst = np.asarray(stream.dst)
    valid = np.asarray(stream.valid)
    weight = np.asarray(stream.weight)
    if waves is None:
        sch = _waves.resolve_schedule(
            src, dst, valid, schedule=None, max_width=max_width,
            telemetry=telemetry,
        )
        rec.add_stage("schedule", sch.schedule_seconds)
        rec.add_stage("pack", sch.pack_seconds)
    else:
        with rec.stage("schedule"):  # precomputed: validation cost only
            sch = _waves.resolve_schedule(
                src, dst, valid, schedule=waves, max_width=max_width,
                telemetry=telemetry,
            )
    with rec.stage("layout"):
        layout = _waves.block_aligned_layout(sch, seg_block)
    plan = mega_plan(cfg.n, cfg.L, layout, packed=packed)
    if plan.nbytes > VMEM_BIT_BUDGET:
        raise ValueError(
            f"matching-bit block {plan.nbytes/2**20:.1f} MiB > VMEM budget; "
            f"use repro.core.rounds with vertex partitioning"
        )
    with rec.stage("layout"):
        # host-side slot prep (all vectorized numpy): flatten the aligned
        # layout; remap padding AND self-loop slots to the sacrificial
        # bit-block row n_pad with w = 0 (duplicate scatter rows must carry
        # identical values, and the kernel has no in-loop self-loop test);
        # pad the tile count up to the grid block — the kernel skips those
        # padding tiles via the prefetched seg_offsets bound. The uv stream
        # is laid out per tile as all bslots u-rows then all bslots v-rows,
        # so the kernel's gather index vector is one contiguous load.
        flat = layout.slots.reshape(-1)
        live = flat >= 0
        pos = flat[live]
        bslots = seg_block * plan.seg
        ntiles_pad = _round_up(max(layout.num_tiles, 1), plan.tiles_per_block)
        total = ntiles_pad * bslots
        sac = np.int32(plan.n_pad)
        uflat = np.full(total, sac, np.int32)
        vflat = np.full(total, sac, np.int32)
        wf = np.zeros((total, 1), np.float32)
        lv = np.zeros(total, bool)
        lv[: flat.size] = live
        u, v, w = src[pos], dst[pos], weight[pos]
        loop = u == v
        uflat[lv] = np.where(loop, sac, u)
        vflat[lv] = np.where(loop, sac, v)
        wf[lv, 0] = np.where(loop, 0.0, w.astype(np.float32))
        uv = np.concatenate(
            [uflat.reshape(ntiles_pad, bslots), vflat.reshape(ntiles_pad, bslots)],
            axis=1,
        ).reshape(-1, 1)
    if telemetry.enabled:
        rec.put_many(_waves.schedule_counters(sch))
        rec.put_many(_waves.layout_counters(layout, sch))
        rec.put_many(plan_counters(plan))
        rec.put("stream.num_edges", stream.num_edges)
        rec.put(
            "traffic.hbm_bytes",
            traffic_bytes(total, int(pos.size), plan.width),
        )
    key = (
        "mega", plan.seg, seg_block, plan.tiles_per_block, plan.n_pad,
        plan.width, plan.words, interpret, packed, total,
        layout.seg_offsets.shape[0], cfg.n, cfg.L, cfg.eps, mb0 is not None,
    )
    with rec.device_stage(key):
        assigned_slots, mb = _mega_device(
            jnp.asarray(layout.seg_offsets),
            jnp.asarray(uv),
            jnp.asarray(wf),
            cfg,
            plan.seg,
            seg_block,
            plan.tiles_per_block,
            plan.n_pad,
            plan.width,
            plan.words,
            interpret,
            packed,
            mb0=None if mb0 is None else jnp.asarray(mb0),
        )
        rec.block((assigned_slots, mb))
    with rec.stage("layout"):
        # slot -> stream-position scatter on the host: each stream position
        # occupies exactly one slot, so this is a plain indexed store
        m = stream.num_edges
        assigned = np.full(m, -1, np.int32)
        assigned[pos] = np.asarray(assigned_slots)[: flat.size][live]
        assigned = jnp.asarray(assigned)
    rec.finish()
    if packed:
        return MatchingResult(assigned=assigned, mb_packed=mb, L=cfg.L)
    return MatchingResult(assigned=assigned, mb=mb)


# --------------------------------------------------------------------------
# Resumable chunked execution.

#: Engines :func:`match_epochs` can drive. The Pallas schedules go
#: through :func:`substream_match`'s machinery; ``scan`` / ``waves_xla``
#: are the XLA engines and ``ref`` the pure-jnp oracle — all accept the
#: carried ``mb0`` state, so every engine is epoch-chunkable.
EPOCH_ENGINES = ("edges", "waves", "mega", "scan", "waves_xla", "ref")


def epoch_bounds(num_edges: int, epochs: int) -> list[int]:
    """Stream positions of the epoch barriers: ``epochs + 1`` monotone
    bounds with near-equal slices (``round(i * m / E)``). Fixed by
    ``(m, E)`` alone, so a resumed run recomputes identical barriers —
    snapshots taken by the crashed run land exactly on them."""
    if epochs < 1:
        raise ValueError(f"epochs must be >= 1, got {epochs}")
    return [round(i * num_edges / epochs) for i in range(epochs + 1)]


def match_epochs(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    *,
    epochs: int = 1,
    engine: str = "mega",
    state=None,
    snapshots=None,
    guard=None,
    packed: bool | None = None,
    interpret: bool | None = None,
    telemetry=obs.DISABLED,
    validate: str = "off",
    on_plan_failure: str = "raise",
    block_e: int | None = None,
    max_width: int | None = None,
    seg_block: int | None = None,
    block_s: int | None = None,
    epoch_hook=None,
) -> MatchingResult:
    """Run Part 1 chunked into ``epochs`` resumable epochs.

    The stream is split at :func:`epoch_bounds`; each epoch runs
    ``engine`` (one of :data:`EPOCH_ENGINES`) on its slice with the
    carried matching bits as ``mb0`` and folds the result into a
    :class:`repro.core.state.MatchState`. Epoch boundaries are barriers,
    so wave scheduling only sees within-epoch conflict chains, and the
    result is **bit-identical to the one-shot run** for every engine:
    greedy matching is confluent in the carried bits, and the recorded
    ``assigned`` slices concatenate (see ``docs/paper_map.md``).

    Resumability:

    * ``snapshots`` (a :class:`repro.checkpoint.snapshots
      .SnapshotManager`) commits the state after every epoch and, when
      ``state`` is not given, resumes from the latest committed
      snapshot — validating its fingerprint against *this* (stream,
      cfg, storage) and replaying only the remaining suffix;
    * ``state`` injects carried state directly (serving-style warm
      resumes); its fingerprint is validated the same way;
    * ``guard`` (a :class:`repro.core.executor.ExecutionGuard`) wraps
      each epoch's device work: per-epoch deadline, bounded retries
      with exponential backoff on transient faults, straggler EWMA.
      Permanent faults are the fallback cascade's job — pass
      ``on_plan_failure="fallback"`` to degrade engines inside the
      epoch instead of failing it.

    ``epoch_hook(epoch_index, state)`` fires after each epoch's
    snapshot commit — the crash-injection seam for the recovery tests
    (faultline's ``kill_at_epoch``). Telemetry: one ``epoch.index``
    event per executed epoch plus the ``epoch.count`` counter;
    ``epochs=1`` with no snapshots/guard is exactly a one-shot call.
    """
    if engine not in EPOCH_ENGINES:
        raise ValueError(f"unknown engine {engine!r}; use {EPOCH_ENGINES}")
    if on_plan_failure not in ("raise", "fallback"):
        raise ValueError(
            f"unknown on_plan_failure {on_plan_failure!r}; "
            f"use 'raise' or 'fallback'"
        )
    if validate != "off":
        from repro.core import guard as _guard

        stream, _ = _guard.validate_stream(
            stream, cfg.n, policy=validate, telemetry=telemetry
        )
    interpret = resolve_interpret(interpret)
    packed = _resolve_packed(cfg, packed)
    if cfg.n == 0:
        return _empty_result(stream, cfg, packed)
    from repro.core.state import MatchState

    template = MatchState.initial(stream, cfg, packed)
    if state is None and snapshots is not None:
        state = snapshots.latest(template)
    if state is None:
        state = template
    elif state.fingerprint != template.fingerprint:
        from repro.checkpoint.snapshots import SnapshotMismatchError

        raise SnapshotMismatchError(
            f"carried state fingerprints {state.fingerprint!r}, run "
            f"fingerprints {template.fingerprint!r} — different stream, "
            f"config, or storage layout"
        )
    m = stream.num_edges
    bounds = epoch_bounds(m, epochs)
    fallback = on_plan_failure == "fallback" and engine in (
        "edges", "waves", "mega",
    )
    for k in range(epochs):
        a, b = max(bounds[k], state.pos), bounds[k + 1]
        if b <= state.pos:
            continue  # already durable in the carried state
        sub = EdgeStream(
            src=stream.src[a:b],
            dst=stream.dst[a:b],
            weight=stream.weight[a:b],
            valid=stream.valid[a:b],
        )
        telemetry.event(
            "epoch.index", epoch=k, start=a, end=b, engine=engine,
        )
        telemetry.count("epoch.count")
        mb0 = state.mb0

        def run_one(sub=sub, mb0=mb0):
            if fallback:
                return _substream_match_fallback(
                    sub, cfg, block_e=block_e, interpret=interpret,
                    packed=packed, schedule=engine, waves=None,
                    max_width=max_width, seg_block=seg_block,
                    block_s=block_s, telemetry=telemetry, mb0=mb0,
                )
            return _run_engine(
                engine, sub, cfg, block_e=block_e, interpret=interpret,
                packed=packed, waves=None, max_width=max_width,
                seg_block=seg_block, block_s=block_s, telemetry=telemetry,
                mb0=mb0,
            )

        out = guard.run(run_one, label=f"epoch[{k}]") if guard else run_one()
        state = state.advance(out, b)
        if snapshots is not None:
            snapshots.save(state)
        if epoch_hook is not None:
            epoch_hook(k, state)
    if snapshots is not None:
        snapshots.wait()
    return state.result()
