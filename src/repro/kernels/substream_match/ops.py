"""Typed / padded entry point for the substream_match Pallas kernel.

VMEM accounting (the §4.3 "storage" analysis, TPU edition)
----------------------------------------------------------
A TPU v5e core has ~16 MiB of VMEM (``VMEM_PER_CORE``). Of that we
reserve ``VMEM_BIT_BUDGET`` (12 MiB) for the resident matching-bit
block and leave the remainder for the edge-stream double buffers that
the Pallas grid pipeline allocates (edges + weights in, assigned out).

Two matching-bit layouts are supported (see :mod:`repro.core.bitpack`):

* ``packed`` (default) — ``mb[n_pad, ceil(L/8)]`` uint8, bit ``j`` of
  word ``k`` = substream ``8k + j``. One byte stores 8 substreams, the
  direct analogue of the paper's L-bit BRAM word; capacity per core is
  8x the unpacked layout (≥ 8x more vertices at any L; 16x at L = 64,
  where the unpacked layout also pays lane padding 64 -> 128).
* ``unpacked`` — ``mb[n_pad, L_pad]`` int8, one byte per substream bit.
  Legacy fallback, selected with ``SubstreamConfig(mb_layout="unpacked")``
  or ``substream_match(..., packed=False)``.

:func:`vmem_plan` is the single source of truth for the block geometry:
it reports the padded shape and byte footprint of the bit block for
either layout and auto-picks ``block_e`` — the edge-block length — from
the VMEM budget the bit block leaves free. Both the kernel wrapper and
the capacity benchmarks (`benchmarks/table6_memory.py`) consume it.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitpack
from repro.core.types import EdgeStream, MatchingResult, SubstreamConfig
from repro.kernels.substream_match import kernel as _kernel

VMEM_PER_CORE = 16 * 2**20  # usable VMEM on a v5e core
VMEM_BIT_BUDGET = 12 * 2**20  # bytes reserved for the matching-bit block
_EDGE_BYTES = 2 * (2 * 4 + 4 + 4)  # (src,dst) i32 + w f32 + assigned i32, x2 buffers


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class VmemPlan:
    """Geometry + budget of the VMEM matching-bit block.

    ``width`` is the padded per-vertex row in bytes (``L_pad`` int8 lanes
    unpacked; ``ceil(L/8)`` rounded up to 8 uint8 words packed), ``words``
    the logical (unpadded) row length, ``nbytes = n_pad * width`` the block
    footprint, and ``block_e`` the auto-picked edge-block length (see
    :func:`vmem_plan` for the selection rule).
    """

    n_pad: int
    width: int
    words: int
    nbytes: int
    block_e: int
    packed: bool

    @property
    def bytes_per_vertex(self) -> int:
        return self.width


def vmem_plan(
    n: int,
    L: int,
    packed: bool = True,
    block_e: int | None = None,
    m: int | None = None,
) -> VmemPlan:
    """Plan the VMEM bit block for ``n`` vertices and ``L`` substreams.

    The auto ``block_e`` is min over three constraints (power of two,
    floor 128): the VMEM the bit block leaves free at ``_EDGE_BYTES``
    per edge, an 8192 cap bounding per-program pipeline latency, and —
    when the stream length ``m`` is given — the smallest power of two
    covering ``m``, so short streams are not padded to a huge block.
    Since the bit block is capped at 12 of 16 MiB, at least 4 MiB stays
    free and the VMEM constraint only binds below ~256 KiB of headroom;
    in practice the 8192 cap or ``m`` decides.
    """
    n_pad = _round_up(max(n, 1), 8)
    if packed:
        words = bitpack.packed_width(max(L, 1))
        width = _round_up(words, 8)
    else:
        words = max(L, 1)
        width = _round_up(words, 128)
    nbytes = n_pad * width
    if block_e is None:
        free = max(VMEM_PER_CORE - min(nbytes, VMEM_BIT_BUDGET), 2**20)
        block_e = 1 << ((free // _EDGE_BYTES).bit_length() - 1)
        block_e = min(block_e, 8192)
        if m is not None:
            block_e = min(block_e, 1 << max(m - 1, 1).bit_length())
        block_e = max(128, block_e)
    return VmemPlan(
        n_pad=n_pad, width=width, words=words, nbytes=nbytes,
        block_e=block_e, packed=packed,
    )


def max_vertices(L: int, packed: bool = True, budget: int = VMEM_BIT_BUDGET) -> int:
    """Largest vertex count whose bit block fits ``budget`` bytes."""
    width = vmem_plan(1, L, packed=packed).width
    return (budget // width) // 8 * 8


@partial(jax.jit, static_argnames=("cfg", "block_e", "interpret", "packed"))
def substream_match(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    block_e: int | None = None,
    interpret: bool = True,
    packed: bool | None = None,
) -> MatchingResult:
    """Run Part 1 on the given stream order via the Pallas kernel.

    ``packed=None`` follows ``cfg.mb_layout``; ``block_e=None`` takes the
    auto-picked value from :func:`vmem_plan`. The packed result carries
    ``mb_packed`` (uint8 bit planes) and unpacks to the bool ``mb`` view
    lazily; both layouts are bit-identical in ``assigned`` and ``mb``.

    Raises at trace time if the bit block exceeds the VMEM budget — at that
    size the caller must vertex-partition (core.rounds) instead.
    """
    if packed is None:
        if cfg.mb_layout not in ("packed", "unpacked"):
            raise ValueError(f"unknown mb_layout {cfg.mb_layout!r}")
        packed = cfg.mb_layout != "unpacked"
    plan = vmem_plan(
        cfg.n, cfg.L, packed=packed, block_e=block_e, m=stream.num_edges
    )
    if plan.nbytes > VMEM_BIT_BUDGET:
        raise ValueError(
            f"matching-bit block {plan.nbytes/2**20:.1f} MiB > VMEM budget; "
            f"use repro.core.rounds with vertex partitioning"
        )
    block_e = plan.block_e
    m = stream.num_edges
    m_pad = _round_up(m, block_e)
    pad = m_pad - m

    edges = jnp.stack([stream.src, stream.dst], axis=1).astype(jnp.int32)
    # invalid edges -> weight 0 (< every threshold, since thresholds >= 1)
    w = jnp.where(stream.valid, stream.weight.astype(jnp.float32), 0.0)
    if pad:
        edges = jnp.concatenate([edges, jnp.zeros((pad, 2), jnp.int32)])
        w = jnp.concatenate([w, jnp.zeros((pad,), jnp.float32)])
    thr = cfg.thresholds()

    if packed:
        # bit-plane thresholds: thr_bits[j, k] = threshold of substream 8k+j
        nbits = plan.width * 8
        thr_flat = jnp.full((nbits,), jnp.inf, jnp.float32).at[: cfg.L].set(thr)
        thr_bits = thr_flat.reshape(plan.width, 8).T
        assigned, mb = _kernel.substream_match_pallas_packed(
            edges, w[:, None], thr_bits, plan.n_pad,
            block_e=block_e, interpret=interpret,
        )
        return MatchingResult(
            assigned=assigned[:m],
            mb_packed=mb[: cfg.n, : plan.words],
            L=cfg.L,
        )

    thr_pad = jnp.full((1, plan.width), jnp.inf, jnp.float32).at[0, : cfg.L].set(thr)
    assigned, mb = _kernel.substream_match_pallas(
        edges, w[:, None], thr_pad, plan.n_pad, block_e=block_e, interpret=interpret
    )
    return MatchingResult(
        assigned=assigned[:m], mb=mb[: cfg.n, : cfg.L].astype(bool)
    )
