"""Typed / padded entry point for the substream_match Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import EdgeStream, MatchingResult, SubstreamConfig
from repro.kernels.substream_match import kernel as _kernel

# v5e VMEM is ~128 MiB/core? No — ~16 MiB usable; leave headroom for the
# edge-block double buffers.
VMEM_BIT_BUDGET = 12 * 2**20  # bytes for the matching-bit block


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def vmem_plan(n: int, L: int) -> tuple[int, int, int]:
    """(n_pad, L_pad, bytes) of the VMEM matching-bit block."""
    L_pad = _round_up(max(L, 1), 128)
    n_pad = _round_up(max(n, 1), 8)
    return n_pad, L_pad, n_pad * L_pad


@partial(jax.jit, static_argnames=("cfg", "block_e", "interpret"))
def substream_match(
    stream: EdgeStream,
    cfg: SubstreamConfig,
    block_e: int = 1024,
    interpret: bool = True,
) -> MatchingResult:
    """Run Part 1 on the given stream order via the Pallas kernel.

    Raises at trace time if the bit block exceeds the VMEM budget — at that
    size the caller must vertex-partition (core.rounds) instead.
    """
    n_pad, L_pad, nbytes = vmem_plan(cfg.n, cfg.L)
    if nbytes > VMEM_BIT_BUDGET:
        raise ValueError(
            f"matching-bit block {nbytes/2**20:.1f} MiB > VMEM budget; "
            f"use repro.core.rounds with vertex partitioning"
        )
    m = stream.num_edges
    m_pad = _round_up(m, block_e)
    pad = m_pad - m

    edges = jnp.stack([stream.src, stream.dst], axis=1).astype(jnp.int32)
    # invalid edges -> weight 0 (< every threshold, since thresholds >= 1)
    w = jnp.where(stream.valid, stream.weight.astype(jnp.float32), 0.0)
    if pad:
        edges = jnp.concatenate([edges, jnp.zeros((pad, 2), jnp.int32)])
        w = jnp.concatenate([w, jnp.zeros((pad,), jnp.float32)])
    thr = cfg.thresholds()
    thr_pad = jnp.full((1, L_pad), jnp.inf, jnp.float32).at[0, : cfg.L].set(thr)

    assigned, mb = _kernel.substream_match_pallas(
        edges, w[:, None], thr_pad, n_pad, block_e=block_e, interpret=interpret
    )
    return MatchingResult(
        assigned=assigned[:m], mb=mb[: cfg.n, : cfg.L].astype(bool)
    )
