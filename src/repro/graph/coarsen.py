"""Graph coarsening by matching — the paper's technique applied to the GNN
substrate (§Arch-applicability in DESIGN.md).

Matched edges are contracted: both endpoints merge into one super-vertex.
Heavy-edge coarsening via MWM is the classic multilevel-partitioning move
(METIS-style); here the matcher *is* the substream-centric algorithm, so
GNN pipelines get a provably-(4+eps)-weight coarsening pass that runs on
the accelerator.
"""
from __future__ import annotations

import numpy as np

from repro.core import (
    EdgeStream,
    SubstreamConfig,
    merge_host,
    mwm_scan,
)


def coarsen_by_matching(src, dst, weight, n: int, L: int = 32, eps: float = 0.1):
    """Returns (mapping [n] -> coarse id, coarse_src, coarse_dst, coarse_w).

    Coarse edge weights are summed over merged multi-edges; intra-cluster
    edges vanish.
    """
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    weight = np.asarray(weight, np.float32)
    stream = EdgeStream.from_numpy(src, dst, weight)
    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    res = mwm_scan(stream, cfg)
    matched = merge_host(stream, res, cfg)

    mapping = np.arange(n, dtype=np.int64)
    for e in matched:
        u, v = src[e], dst[e]
        mapping[max(u, v)] = min(u, v)
    # compress ids
    uniq, mapping = np.unique(mapping, return_inverse=True)
    cs, cd = mapping[src], mapping[dst]
    keep = cs != cd
    cs, cd, cw = cs[keep], cd[keep], weight[keep]
    lo, hi = np.minimum(cs, cd), np.maximum(cs, cd)
    key = lo * len(uniq) + hi
    order = np.argsort(key, kind="stable")
    key, lo, hi, cw = key[order], lo[order], hi[order], cw[order]
    boundary = np.concatenate([[True], key[1:] != key[:-1]])
    group = np.cumsum(boundary) - 1
    agg_w = np.zeros(group[-1] + 1 if len(group) else 0, np.float32)
    np.add.at(agg_w, group, cw)
    return mapping, lo[boundary], hi[boundary], agg_w
