"""Wave decomposition of an edge stream into conflict-free batches.

The paper's edge processor (§4.4) consumes one edge per cycle because
consecutive stream edges may share a vertex and therefore race on the
same matching-bit row. But greedy matching w.r.t. a fixed edge order is
*confluent* over vertex-disjoint edges: if no two edges of a batch share
an endpoint, processing the batch in any order — or simultaneously —
yields bit-identical matching bits and recorded lists. So the stream can
be cut into **waves**: the greedy level assignment

    wave(e) = 1 + max(last_wave[u], last_wave[v])

(the longest conflict chain ending at ``e``) groups edges such that every
wave is vertex-disjoint while conflicting edges keep their stream order
across waves. A wave then updates the whole matching-bit block in one
shot — the TPU analogue of the intra-pipeline parallelism FAST extracts
from its partitioned CST pipelines: inner-loop trips drop from ``m`` to
``#waves`` (≈ the maximum *weighted* degree of the conflict graph,
typically orders of magnitude smaller), and each trip is full-width
vector work instead of a scalar row update.

This module is pure scheduling — numpy in, numpy out, no dependency on
:mod:`repro.core` — so both the XLA reference (`repro.core.matching.
mwm_waves`), the Pallas kernels (`repro.kernels.substream_match`) and
the rounds engine (`repro.core.rounds`) can share one schedule. The
assignment loop is host-side sequential (it *is* the dependency chain),
mirroring the CPU-side sorter the paper already assumes for the §4.2
lexicographic order; schedules are reusable across `L`/`eps` sweeps
because they depend only on the edge endpoints and order.
"""
from __future__ import annotations

import dataclasses

import numpy as np

#: Default cap on edges per wave. Splitting an oversized wave into
#: ``max_width`` chunks keeps the [W, width] gather tiles VMEM-bounded
#: and bounds padding waste on skewed graphs; chunks of a vertex-disjoint
#: set are themselves vertex-disjoint, so correctness is unaffected.
#: Every wave is padded to ONE global width (= the largest wave after
#: splitting), so on skewed graphs — a few huge waves, many tiny ones —
#: lower ``max_width`` toward the typical wave size and watch
#: ``WaveSchedule.fill``: slot memory and per-wave kernel work scale
#: with ``num_waves * width``, not with the edge count.
MAX_WIDTH = 512

#: Wave widths are padded to a multiple of this (TPU sublane friendliness).
WIDTH_ALIGN = 8


@dataclasses.dataclass(frozen=True)
class WaveSchedule:
    """A conflict-free wave decomposition of one edge stream.

    ``wave`` int32 [m]: wave id per stream position (-1 = unscheduled,
    i.e. a padding edge). ``order`` int32 [num_scheduled]: stream
    positions sorted by (wave, stream position) — the wave-major
    permutation. ``offsets`` int32 [num_waves + 1]: CSR offsets of each
    wave inside ``order``. ``slots`` int32 [num_waves, width]: the same
    data padded to the fixed width ``width`` with -1 in empty slots —
    the gather map every vectorized consumer uses.
    """

    wave: np.ndarray
    order: np.ndarray
    offsets: np.ndarray
    slots: np.ndarray
    num_edges: int

    @property
    def num_waves(self) -> int:
        return self.slots.shape[0]

    @property
    def width(self) -> int:
        return self.slots.shape[1]

    @property
    def num_scheduled(self) -> int:
        return int(self.order.shape[0])

    @property
    def fill(self) -> float:
        """Fraction of slots holding a real edge (1.0 = no padding)."""
        total = self.slots.size
        return self.num_scheduled / total if total else 1.0

    def wave_sizes(self) -> np.ndarray:
        return np.diff(self.offsets)


def wave_schedule(
    src,
    dst,
    valid=None,
    order=None,
    max_width: int = MAX_WIDTH,
    width_align: int = WIDTH_ALIGN,
) -> WaveSchedule:
    """Decompose a stream into vertex-disjoint waves.

    ``order`` (optional int array [m]) pre-permutes the stream — e.g.
    ``repro.core.blocked.lexicographic_order`` — so the waves respect the
    *processing* order rather than the arrival order; the returned
    schedule still indexes original stream positions. ``valid`` masks
    padding edges, which are left unscheduled (``wave == -1``).

    Every edge is placed one wave past the last wave touching either
    endpoint, so any two edges sharing a vertex land in distinct waves in
    stream order, while independent edges pack together. Waves larger
    than ``max_width`` are split into chunks (still vertex-disjoint).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    m = src.shape[0]
    if dst.shape[0] != m:
        raise ValueError(f"src/dst length mismatch: {m} vs {dst.shape[0]}")
    if max_width < 1:
        raise ValueError(f"max_width must be >= 1, got {max_width}")
    valid_np = (
        np.ones(m, dtype=bool) if valid is None else np.asarray(valid, dtype=bool)
    )
    positions = np.arange(m) if order is None else np.asarray(order, dtype=np.int64)

    n_hint = int(max(src.max(), dst.max())) + 1 if m else 1
    last_wave = np.full(n_hint, -1, dtype=np.int64)
    counts: list[int] = []  # population per wave, for max_width splitting
    # skip pointers over full waves (interval union-find with path
    # halving): parent[k] == k while wave k is open, else the next
    # candidate. Full waves never reopen, so amortized near-O(1) per edge
    # — a linear "first open wave >= w" scan is quadratic on streams of
    # mostly-independent edges, which all target the lowest waves.
    parent: list[int] = []
    wave = np.full(m, -1, dtype=np.int64)

    def _find_open(k: int) -> int:
        while k < len(counts) and parent[k] != k:
            nxt = parent[k]
            if nxt < len(counts) and parent[nxt] != nxt:
                parent[k] = parent[nxt]
            k = nxt
        return k

    for e in positions.tolist():
        if not valid_np[e]:
            continue
        u = src[e]
        v = dst[e]
        w = _find_open(1 + max(last_wave[u], last_wave[v]))
        if w == len(counts):
            counts.append(0)
            parent.append(w)
        counts[w] += 1
        if counts[w] >= max_width:
            parent[w] = w + 1
        wave[e] = w
        last_wave[u] = w
        last_wave[v] = w

    num_waves = len(counts)
    scheduled = np.nonzero(wave >= 0)[0]
    # wave-major, stream-position-minor: stable sort on the wave key alone
    # (``scheduled`` is already ascending in stream position)
    order_out = scheduled[np.argsort(wave[scheduled], kind="stable")]
    offsets = np.zeros(num_waves + 1, dtype=np.int64)
    np.cumsum(np.asarray(counts, dtype=np.int64), out=offsets[1:])

    width = int(max(counts)) if counts else 1
    width = -(-width // width_align) * width_align
    slots = np.full((num_waves, width), -1, dtype=np.int64)
    if num_waves:
        sizes = np.diff(offsets)
        col = np.arange(len(order_out)) - np.repeat(offsets[:-1], sizes)
        slots[wave[order_out], col] = order_out

    return WaveSchedule(
        wave=wave.astype(np.int32),
        order=order_out.astype(np.int32),
        offsets=offsets.astype(np.int32),
        slots=slots.astype(np.int32),
        num_edges=m,
    )


def validate_schedule(schedule: WaveSchedule, src, dst, valid=None) -> None:
    """Vectorized safety check that ``schedule`` fits this stream.

    Guards the documented reuse path (precomputed schedules amortized
    across runs) against stale schedules — e.g. one built for a stream
    that was permuted afterwards. A non-disjoint wave would corrupt the
    engines silently (the kernels' scatter-add relies on disjointness),
    so this raises instead. Checks length, that exactly the valid edges
    are scheduled, and per-wave vertex-disjointness — all O(m log W)
    numpy, negligible next to a kernel run. Deliberately does NOT pin
    the conflict order to stream order: schedules built over an explicit
    processing ``order`` are legitimate and simply realize the greedy
    matching of that order.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    m = schedule.num_edges
    if src.shape[0] != m:
        raise ValueError(
            f"wave schedule built for {m} edges, stream has {src.shape[0]}"
        )
    valid_np = np.ones(m, bool) if valid is None else np.asarray(valid, bool)
    if not np.array_equal(schedule.wave >= 0, valid_np):
        raise ValueError(
            "wave schedule does not cover exactly this stream's valid "
            "edges; rebuild the schedule for the current stream"
        )
    slots = schedule.slots
    if slots.size == 0:
        return
    ok = slots >= 0
    safe = np.maximum(slots, 0)
    u = np.where(ok, src[safe], 0).astype(np.int64)
    v = np.where(ok, dst[safe], 0).astype(np.int64)
    W = slots.shape[1]
    # empty slots and self-loop second endpoints get per-column negative
    # sentinels, then any duplicate in a sorted row is a real conflict
    sentinel = -(np.arange(2 * W, dtype=np.int64)[None, :] + 2)
    verts = np.concatenate([u, v], axis=1)
    keep = np.concatenate([ok, ok & (u != v)], axis=1)
    verts = np.where(keep, verts, sentinel)
    verts.sort(axis=1)
    if (verts[:, 1:] == verts[:, :-1]).any():
        raise ValueError(
            "wave schedule is not vertex-disjoint for this stream "
            "(stale or built for a different edge order); rebuild it "
            "with wave_schedule on the current stream"
        )


def resolve_schedule(
    src,
    dst,
    valid,
    schedule: WaveSchedule | None = None,
    max_width: int | None = None,
) -> WaveSchedule:
    """Build a schedule for the stream, or validate a precomputed one.

    The single entry every wave consumer (`mwm_waves`, the Pallas wave
    path, rounds-with-waves) goes through, so the validation rules stay
    in one place.
    """
    if schedule is None:
        kw = {} if max_width is None else {"max_width": max_width}
        return wave_schedule(src, dst, valid=valid, **kw)
    validate_schedule(schedule, src, dst, valid)
    return schedule


def scatter_slot_assignments(slots, vals, m: int):
    """Scatter per-slot kernel outputs back to stream positions.

    ``slots`` int [..., W] maps slots to stream positions (-1 = padding),
    ``vals`` the matching per-slot assigned indices (>= -1). Returns
    int32 [m] with -1 for unscheduled edges. Padding slots alias position
    0 with value -1, so the max-scatter makes them exact no-ops. Safe
    inside jit (pure jnp).
    """
    import jax.numpy as jnp

    flat = slots.reshape(-1)
    vals = vals.reshape(-1)[: flat.shape[0]]
    live = flat >= 0
    return (
        jnp.full((m,), -1, jnp.int32)
        .at[jnp.where(live, flat, 0)]
        .max(jnp.where(live, vals, -1))
    )


def slot_arrays(schedule: WaveSchedule, src, dst, weight, valid=None):
    """Gather per-slot endpoint/weight arrays for vectorized consumers.

    Returns numpy ``(u, v, w, ok)``, each shaped [num_waves, width].
    Padding slots get ``u == v == 0`` and ``w == 0`` — below every
    substream threshold and a self-loop besides, so they can never match
    (both the XLA and Pallas wave engines rely on this encoding).
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    weight = np.asarray(weight)
    slots = schedule.slots
    ok = slots >= 0
    if valid is not None:
        ok = ok & np.where(slots >= 0, np.asarray(valid, bool)[np.maximum(slots, 0)], False)
    safe = np.maximum(slots, 0)
    u = np.where(ok, src[safe], 0).astype(np.int32)
    v = np.where(ok, dst[safe], 0).astype(np.int32)
    w = np.where(ok, weight[safe], 0).astype(np.float32)
    return u, v, w, ok


def check_schedule(schedule: WaveSchedule, src, dst, valid=None, order=None) -> None:
    """Assert the wave invariants (used by tests; cheap, host-side).

    * every scheduled wave is vertex-disjoint (self-loops use one slot);
    * conflicting edges appear in processing order across waves
      (``order`` is the explicit permutation the schedule was built
      with, if any — stream order otherwise);
    * ``order``/``offsets``/``slots`` describe the same decomposition.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    wave = schedule.wave
    if valid is not None:
        valid = np.asarray(valid, bool)
        assert (wave[~valid] == -1).all(), "padding edges must be unscheduled"
        assert (wave[valid] >= 0).all(), "valid edges must be scheduled"
    for k in range(schedule.num_waves):
        members = schedule.order[schedule.offsets[k] : schedule.offsets[k + 1]]
        assert (wave[members] == k).all()
        verts = []
        for e in members.tolist():
            verts.append(src[e])
            if dst[e] != src[e]:
                verts.append(dst[e])
        assert len(verts) == len(set(verts)), f"wave {k} not vertex-disjoint"
        row = schedule.slots[k]
        assert (np.sort(row[row >= 0]) == np.sort(members)).all()
    # order preservation among conflicting edges (in processing order)
    positions = (
        np.nonzero(wave >= 0)[0]
        if order is None
        else np.asarray(order)[wave[np.asarray(order)] >= 0]
    )
    touch: dict[int, int] = {}
    for e in positions.tolist():
        for x in {int(src[e]), int(dst[e])}:
            if x in touch:
                assert wave[touch[x]] < wave[e], (
                    f"edges {touch[x]} and {e} share vertex {x} but waves "
                    f"{wave[touch[x]]} >= {wave[e]}"
                )
            touch[x] = e
