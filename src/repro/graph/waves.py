"""Fill-packed wave decomposition of an edge stream into conflict-free batches.

The paper's edge processor (§4.4) consumes one edge per cycle because
consecutive stream edges may share a vertex and therefore race on the
same matching-bit row. But greedy matching w.r.t. a fixed edge order is
*confluent* over vertex-disjoint edges: if no two edges of a batch share
an endpoint, processing the batch in any order — or simultaneously —
yields bit-identical matching bits and recorded lists. So the stream can
be cut into **waves** such that every wave is vertex-disjoint while
conflicting edges keep their stream order across waves.

Scheduling (the tentpole of this module) is earliest-fit packing:
every edge goes into the earliest wave that is

* at or past its **conflict depth** — one past the wave of every earlier
  edge sharing an endpoint, tracked with per-vertex next-free-wave
  pointers, and
* not **full** — when ``max_width`` caps wave occupancy, full waves are
  skipped via an interval-union skip list, so scheduling stays near-O(m).

With no occupancy cap (the default) earliest-fit collapses to the pure
conflict-depth assignment, which is *provably minimal*: the wave count
equals the longest conflict chain (≥ the maximum vertex multiplicity —
every edge at the hub vertex needs its own wave), so no valid
vertex-disjoint decomposition can use fewer waves. The depth pass is
fully vectorized as numpy batch passes over ready edges (an indegree
peel of the 2-predecessor conflict DAG), replacing the former per-edge
Python loop; the capped path keeps the sequential earliest-fit packer.

Layout (where the "fill-packed" in the title lives): waves are *not*
padded to one global maximum width. They are packed back-to-back into
fixed-size **segments** of ``SEG`` slots (a wave of size s occupies
``ceil(s / SEG)`` segments; only its last segment carries padding), so
``slots`` is ``[num_segments, SEG]`` and the fill — the fraction of
slots holding a real edge — stays high regardless of wave-size skew.
Each segment is a *subset* of one wave and therefore vertex-disjoint
itself: every consumer that processed "one slots-row at a time"
(the XLA wave scan, the Pallas segment kernel, rounds-with-waves) keeps
its row-major contract unchanged, with per-row traffic proportional to
``SEG`` instead of the largest wave.

This module is pure scheduling — numpy in, numpy out, no dependency on
:mod:`repro.core` — so the XLA reference (`repro.core.matching.
mwm_waves`), the Pallas kernels (`repro.kernels.substream_match`) and
the rounds engine (`repro.core.rounds`) share one schedule. Schedules
are reusable across `L`/`eps` sweeps because they depend only on the
edge endpoints and order.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro import obs

#: Slots per segment — the row width of ``WaveSchedule.slots`` and the
#: trip unit of every vectorized consumer. Waves are padded only up to
#: the next multiple of ``SEG`` (not to a global max), so per-wave
#: padding is < SEG slots. 8 matches the TPU sublane granularity the
#: old ``WIDTH_ALIGN`` targeted.
SEG = 8

#: Back-compat alias (schedule widths are multiples of this).
WIDTH_ALIGN = SEG

@dataclasses.dataclass(frozen=True)
class WaveSchedule:
    """A conflict-free, fill-packed wave decomposition of one edge stream.

    ``wave`` int32 [m]: wave id per stream position (-1 = unscheduled,
    i.e. a padding edge). ``order`` int32 [num_scheduled]: stream
    positions sorted by (wave, stream position) — the wave-major
    permutation. ``offsets`` int32 [num_waves + 1]: CSR offsets of each
    wave inside ``order``. ``slots`` int32 [num_segments, SEG]: the
    packed slot layout — wave k occupies segment rows
    ``seg_offsets[k] : seg_offsets[k + 1]`` back-to-back, -1 in the
    (< SEG) padding slots at its tail. Every row is vertex-disjoint (a
    subset of one wave), which is the only invariant row-major consumers
    need.

    ``schedule_seconds`` / ``pack_seconds`` record the host cost of the
    assignment and layout phases. **Deprecated**: both are views of the
    one telemetry timing path (:class:`repro.obs.stopwatch` spans
    ``wave_schedule.assign`` / ``wave_schedule.pack``) kept populated
    for compatibility — new consumers should pass ``telemetry=`` to
    :func:`wave_schedule` and read the spans or
    ``MatchTelemetry.stage_seconds`` instead.
    """

    wave: np.ndarray
    order: np.ndarray
    offsets: np.ndarray
    slots: np.ndarray
    seg_offsets: np.ndarray
    num_edges: int
    schedule_seconds: float = 0.0
    pack_seconds: float = 0.0

    @property
    def num_waves(self) -> int:
        return int(self.offsets.shape[0]) - 1

    @property
    def num_segments(self) -> int:
        return int(self.slots.shape[0])

    @property
    def width(self) -> int:
        """Slots per segment row (= ``SEG``; kept as the legacy name)."""
        return int(self.slots.shape[1])

    @property
    def num_scheduled(self) -> int:
        return int(self.order.shape[0])

    @property
    def fill(self) -> float:
        """Fraction of slots holding a real edge (1.0 = no padding)."""
        total = self.slots.size
        return self.num_scheduled / total if total else 1.0

    def wave_sizes(self) -> np.ndarray:
        return np.diff(self.offsets)

    @property
    def max_wave_size(self) -> int:
        sizes = self.wave_sizes()
        return int(sizes.max()) if sizes.size else 0


def _conflict_links(su: np.ndarray, sv: np.ndarray):
    """Successor links of the conflict DAG over ranks 0..k-1.

    Edge r (endpoints ``su[r]``, ``sv[r]``) conflicts with the previous
    and next edge touching either endpoint. Returns (succ int32 [k, 2],
    pred_count int32 [k]): ``succ[r, s]`` is the rank of the next edge
    at r's endpoint s (-1 = none), ``pred_count[r]`` how many earlier
    edges r directly waits on (0, 1, or 2). Self-loops contribute one
    endpoint entry, so an edge never depends on itself.
    """
    k = su.shape[0]
    loop = su == sv
    ranks = np.arange(k, dtype=np.int64)
    vert = np.concatenate([su, sv[~loop]])
    rank = np.concatenate([ranks, ranks[~loop]])
    side = np.concatenate(
        [np.zeros(k, np.int8), np.ones(int((~loop).sum()), np.int8)]
    )
    o = np.lexsort((rank, vert))
    vo, ro, so = vert[o], rank[o], side[o]
    same = np.empty(len(o), bool)
    if len(o):
        same[0] = False
        same[1:] = vo[1:] == vo[:-1]
    i = np.nonzero(same)[0]
    succ = np.full((k, 2), -1, np.int64)
    succ[ro[i - 1], so[i - 1]] = ro[i]
    pred_count = np.zeros(k, np.int64)
    np.add.at(pred_count, ro[i], 1)
    return succ, pred_count


def _assign_depth_batched(su: np.ndarray, sv: np.ndarray) -> np.ndarray:
    """Conflict depth per rank via numpy batch passes over ready edges.

    Pass t resolves exactly the edges of depth t (an edge is ready once
    every earlier edge sharing an endpoint has a depth, and its depth is
    one past its deepest predecessor — so the ready frontier of pass t
    IS depth level t). Each edge enters the frontier once and notifies
    at most two successors, so total element work is O(m) spread over
    ``depth_max`` vectorized passes — no per-edge Python loop.
    """
    k = su.shape[0]
    depth = np.zeros(k, np.int64)
    if k == 0:
        return depth
    succ, waiting = _conflict_links(su, sv)
    frontier = np.nonzero(waiting == 0)[0]
    d = -1
    while frontier.size:
        d += 1
        depth[frontier] = d
        nxt = succ[frontier].reshape(-1)
        nxt = nxt[nxt >= 0]
        if not nxt.size:
            break
        np.subtract.at(waiting, nxt, 1)
        frontier = nxt[waiting[nxt] == 0]
        if frontier.size > 1:
            # a rank occurs twice in ``nxt`` when both of its
            # predecessors resolved this pass
            frontier = np.unique(frontier)
    return depth


def _assign_earliest_fit(
    su: np.ndarray, sv: np.ndarray, max_width: int
) -> np.ndarray:
    """Sequential earliest-fit packer with per-wave occupancy ``max_width``.

    Every edge lands in the earliest wave at or past its conflict depth
    (per-vertex next-free-wave pointers in ``avail``) that still has a
    free slot. Full waves never reopen, so they are skipped with an
    interval union (path-halving) — amortized near-O(1) per edge, where
    a linear "first open wave" rescan would be quadratic on streams of
    mostly-independent edges that all target the lowest waves.
    """
    k = su.shape[0]
    n_hint = int(max(su.max(), sv.max())) + 1 if k else 1
    avail = np.zeros(n_hint, dtype=np.int64)  # next free wave per vertex
    counts: list[int] = []  # occupancy per wave
    parent: list[int] = []  # skip pointers over full waves
    wave = np.empty(k, dtype=np.int64)

    def _find_open(w: int) -> int:
        while w < len(counts) and parent[w] != w:
            nxt = parent[w]
            if nxt < len(counts) and parent[nxt] != nxt:
                parent[w] = parent[nxt]
            w = nxt
        return w

    for r in range(k):
        u = su[r]
        v = sv[r]
        w = _find_open(int(max(avail[u], avail[v])))
        if w == len(counts):
            counts.append(0)
            parent.append(w)
        counts[w] += 1
        if counts[w] >= max_width:
            parent[w] = w + 1
        wave[r] = w
        avail[u] = w + 1
        avail[v] = w + 1
    return wave


def wave_schedule(
    src,
    dst,
    valid=None,
    order=None,
    max_width: int | None = None,
    seg: int = SEG,
    telemetry=obs.DISABLED,
) -> WaveSchedule:
    """Decompose a stream into vertex-disjoint, fill-packed waves.

    ``order`` (optional int array [m]) pre-permutes the stream — e.g.
    ``repro.core.blocked.lexicographic_order`` — so the waves respect the
    *processing* order rather than the arrival order; the returned
    schedule still indexes original stream positions. ``valid`` masks
    padding edges, which are left unscheduled (``wave == -1``).

    ``max_width`` (default None = uncapped) bounds per-wave occupancy
    via the sequential earliest-fit packer; uncapped scheduling is the
    vectorized conflict-depth assignment, which is wave-count minimal.
    Either way every edge is placed at or past its conflict depth, so
    any two edges sharing a vertex land in distinct waves in processing
    order while independent edges pack together. ``seg`` is the slot
    width of the packed layout (see :data:`SEG`).

    ``telemetry`` records the two host phases as spans
    (``wave_schedule.assign`` / ``wave_schedule.pack``) plus the
    schedule geometry counters; the deprecated ``schedule_seconds`` /
    ``pack_seconds`` fields are populated from the *same* stopwatch
    measurements, so there is one timing path either way.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    m = src.shape[0]
    if dst.shape[0] != m:
        raise ValueError(f"src/dst length mismatch: {m} vs {dst.shape[0]}")
    if max_width is not None and max_width < 1:
        raise ValueError(f"max_width must be >= 1, got {max_width}")
    if seg < 1:
        raise ValueError(f"seg must be >= 1, got {seg}")
    valid_np = (
        np.ones(m, dtype=bool) if valid is None else np.asarray(valid, dtype=bool)
    )
    positions = np.arange(m) if order is None else np.asarray(order, dtype=np.int64)
    positions = positions[valid_np[positions]]

    with obs.stopwatch(telemetry, "wave_schedule.assign") as sw_assign:
        su = src[positions]
        sv = dst[positions]
        if max_width is None:
            wave_of_rank = _assign_depth_batched(su, sv)
        else:
            wave_of_rank = _assign_earliest_fit(su, sv, max_width)
        wave = np.full(m, -1, dtype=np.int64)
        wave[positions] = wave_of_rank

    with obs.stopwatch(telemetry, "wave_schedule.pack") as sw_pack:
        num_waves = int(wave_of_rank.max()) + 1 if wave_of_rank.size else 0
        scheduled = np.nonzero(wave >= 0)[0]
        # wave-major, stream-position-minor: stable sort on the wave key alone
        # (``scheduled`` is already ascending in stream position)
        order_out = scheduled[np.argsort(wave[scheduled], kind="stable")]
        counts = np.bincount(wave[scheduled], minlength=max(num_waves, 1))[:num_waves]
        offsets = np.zeros(num_waves + 1, dtype=np.int64)
        np.cumsum(counts, out=offsets[1:])

        # fill-packed layout: wave k occupies ceil(counts[k] / seg) segment
        # rows back-to-back; only its last row carries (< seg) padding
        seg_counts = -(-counts // seg)
        seg_offsets = np.zeros(num_waves + 1, dtype=np.int64)
        np.cumsum(seg_counts, out=seg_offsets[1:])
        num_segments = int(seg_offsets[-1])
        slots = np.full((num_segments, seg), -1, dtype=np.int64)
        if num_segments:
            within = np.arange(len(order_out)) - np.repeat(offsets[:-1], counts)
            row = np.repeat(seg_offsets[:-1], counts) + within // seg
            slots[row, within % seg] = order_out

    schedule = WaveSchedule(
        wave=wave.astype(np.int32),
        order=order_out.astype(np.int32),
        offsets=offsets.astype(np.int32),
        slots=slots.astype(np.int32),
        seg_offsets=seg_offsets.astype(np.int32),
        num_edges=m,
        schedule_seconds=sw_assign.seconds,
        pack_seconds=sw_pack.seconds,
    )
    if telemetry.enabled:
        telemetry.counters.update(schedule_counters(schedule))
    return schedule


def schedule_counters(schedule: WaveSchedule) -> dict:
    """The schedule-geometry counter set (``schedule.*``).

    Bit-exact copies of the schedule's own accounting — the telemetry
    layer's view of what the scheduler already computed (and used to
    throw away). Shared by :func:`wave_schedule` and the engine
    recorders in ``kernels/substream_match/ops.py``.
    """
    return {
        "schedule.num_edges": int(schedule.num_edges),
        "schedule.num_waves": int(schedule.num_waves),
        "schedule.num_segments": int(schedule.num_segments),
        "schedule.seg_width": int(schedule.width),
        "schedule.num_scheduled": int(schedule.num_scheduled),
        "schedule.padding_slots": int(schedule.slots.size - schedule.num_scheduled),
        "schedule.max_wave_size": int(schedule.max_wave_size),
        "schedule.fill": float(schedule.fill),
    }


def layout_counters(layout: "BlockAlignedLayout", schedule: WaveSchedule) -> dict:
    """The block-aligned layout counter set (``layout.*``) — the mega
    path's extra padding accounting on top of :func:`schedule_counters`."""
    live = int((layout.slots >= 0).sum())
    return {
        "layout.num_tiles": int(layout.num_tiles),
        "layout.num_segments": int(layout.num_segments),
        "layout.seg_block": int(layout.seg_block),
        "layout.padding_rows": int(layout.num_segments - schedule.num_segments),
        "layout.padding_slots": int(layout.slots.size - live),
        "layout.fill": float(layout.fill),
    }


def validate_schedule(schedule: WaveSchedule, src, dst, valid=None) -> None:
    """Vectorized safety check that ``schedule`` fits this stream.

    Guards the documented reuse path (precomputed schedules amortized
    across runs) against stale schedules — e.g. one built for a stream
    that was permuted afterwards. A non-disjoint wave would corrupt the
    engines silently (the kernels' row-addressed scatter relies on
    disjointness), so this raises instead. Checks length, that exactly
    the valid edges are scheduled, and per-wave vertex-disjointness —
    all O(m log m) numpy, negligible next to a kernel run. Deliberately
    does NOT pin the conflict order to stream order: schedules built
    over an explicit processing ``order`` are legitimate and simply
    realize the greedy matching of that order.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    m = schedule.num_edges
    if src.shape[0] != m:
        raise ValueError(
            f"wave schedule built for {m} edges, stream has {src.shape[0]}"
        )
    valid_np = np.ones(m, bool) if valid is None else np.asarray(valid, bool)
    if not np.array_equal(schedule.wave >= 0, valid_np):
        raise ValueError(
            "wave schedule does not cover exactly this stream's valid "
            "edges; rebuild the schedule for the current stream"
        )
    order = schedule.order
    # the engines gather from ``slots``, so check it agrees with the
    # wave-major permutation (its non-padding entries ARE ``order``) —
    # a schedule whose derived fields drifted from its slot layout would
    # otherwise pass the wave checks below and still corrupt the gather
    flat = schedule.slots.reshape(-1)
    if not np.array_equal(flat[flat >= 0], order):
        raise ValueError(
            "wave schedule slot layout disagrees with its wave order "
            "(corrupted or hand-built schedule); rebuild it with "
            "wave_schedule on the current stream"
        )
    if order.size == 0:
        return
    # order must be in-range and duplicate-free BEFORE it is used to
    # index the stream: a negative entry would silently wrap through
    # numpy indexing (src[-5] is a real edge) and corrupt the gather
    # with no error — the exact failure mode this check exists to stop
    if order.min() < 0 or order.max() >= m or np.unique(order).size != order.size:
        raise ValueError(
            "wave schedule order is not a permutation of edge indices "
            "(out-of-range or duplicate entries; corrupted or "
            "hand-built schedule); rebuild it with wave_schedule on "
            "the current stream"
        )
    # per-wave disjointness: sort (wave, vertex) pairs over both
    # endpoints (self-loops contribute one), adjacent duplicates are
    # conflicts. Checked over the full wave, not just segment rows —
    # strictly stronger than what the row-major consumers need. The two
    # keys are fused into one int64 (vertex ids fit far below 2**31 and
    # wave ids below m, so wave * (max_vertex + 1) + vertex cannot
    # overflow or collide) — one np.sort instead of a two-pass lexsort,
    # which halves the dominant host cost every engine pays per call on
    # the precomputed-schedule path.
    u = src[order].astype(np.int64)
    v = dst[order].astype(np.int64)
    w_ids = schedule.wave[order].astype(np.int64)
    keep = u != v
    verts = np.concatenate([u, v[keep]])
    waves = np.concatenate([w_ids, w_ids[keep]])
    key = np.sort(waves * (int(verts.max()) + 1) + verts)
    dup = key[1:] == key[:-1]
    if dup.any():
        raise ValueError(
            "wave schedule is not vertex-disjoint for this stream "
            "(stale or built for a different edge order); rebuild it "
            "with wave_schedule on the current stream"
        )


def resolve_schedule(
    src,
    dst,
    valid,
    schedule: WaveSchedule | None = None,
    max_width: int | None = None,
    telemetry=obs.DISABLED,
) -> WaveSchedule:
    """Build a schedule for the stream, or validate a precomputed one.

    The single entry every wave consumer (`mwm_waves`, the Pallas wave
    path, rounds-with-waves) goes through, so the validation rules stay
    in one place. ``telemetry`` records the build (or validation) cost
    as ``wave_schedule.*`` spans.
    """
    if schedule is None:
        return wave_schedule(
            src, dst, valid=valid, max_width=max_width, telemetry=telemetry
        )
    with telemetry.span("wave_schedule.validate"):
        validate_schedule(schedule, src, dst, valid)
    return schedule


@dataclasses.dataclass(frozen=True)
class BlockAlignedLayout:
    """A :class:`WaveSchedule` slot layout re-padded to ``seg_block`` tiles.

    The megakernel (`repro.kernels.substream_match`) consumes the slot
    stream one *tile* — ``seg_block`` consecutive segment rows, i.e.
    ``seg_block * SEG`` slots — per gather/compute/scatter op. A tile op
    is only safe when every slot in the tile is vertex-disjoint, which
    holds exactly when no tile straddles a wave boundary. This layout
    therefore pads each wave's segment-row run up to the next
    ``seg_block`` multiple (padding rows are all ``-1``), so

    * ``slots`` is ``[num_tiles * seg_block, SEG]`` int32; rows
      ``seg_offsets[k] : seg_offsets[k + 1]`` belong to wave ``k`` and
      that range length is a ``seg_block`` multiple;
    * ``seg_offsets`` int32 [num_waves + 1] is monotone, block-aligned
      (every entry a ``seg_block`` multiple), and its last entry is the
      total aligned segment count;
    * every stream position scheduled by the source schedule occupies
      exactly one slot (padding only ever *adds* ``-1`` slots).

    ``fill`` is the real-edge fraction of the aligned layout — always
    ≤ the source schedule's fill; the megakernel trades it for a
    ~``seg_block``× cut in sequential tile trips.
    """

    slots: np.ndarray
    seg_offsets: np.ndarray
    seg_block: int
    num_edges: int

    @property
    def num_tiles(self) -> int:
        return int(self.slots.shape[0]) // self.seg_block

    @property
    def num_segments(self) -> int:
        return int(self.slots.shape[0])

    @property
    def width(self) -> int:
        return int(self.slots.shape[1])

    @property
    def fill(self) -> float:
        total = self.slots.size
        return int((self.slots >= 0).sum()) / total if total else 1.0


def block_aligned_layout(
    schedule: WaveSchedule, seg_block: int
) -> BlockAlignedLayout:
    """Re-pad ``schedule.slots`` so every wave spans whole tiles.

    Pure numpy re-layout (no re-scheduling): wave ``k``'s segment rows
    are copied back-to-back to a ``seg_block``-aligned base row and the
    gap up to the next aligned base is left as ``-1`` padding rows. The
    result is the megakernel's HBM slot stream: consecutive groups of
    ``seg_block`` rows ("tiles") never straddle a wave, so each tile is
    vertex-disjoint and one ``[seg_block * SEG, width]`` tile op per
    trip is bit-identical to the sequential scan.
    """
    if seg_block < 1:
        raise ValueError(f"seg_block must be >= 1, got {seg_block}")
    seg = schedule.width
    segc = np.diff(schedule.seg_offsets).astype(np.int64)
    segc_aligned = -(-segc // seg_block) * seg_block
    offsets = np.zeros(segc_aligned.shape[0] + 1, np.int64)
    np.cumsum(segc_aligned, out=offsets[1:])
    total = int(offsets[-1])
    slots = np.full((total, seg), -1, np.int64)
    if schedule.num_segments:
        src_rows = np.arange(schedule.num_segments, dtype=np.int64)
        wave_of_row = np.repeat(
            np.arange(schedule.num_waves, dtype=np.int64), segc
        )
        dst_rows = offsets[wave_of_row] + (
            src_rows - schedule.seg_offsets[wave_of_row]
        )
        slots[dst_rows] = schedule.slots
    return BlockAlignedLayout(
        slots=slots.astype(np.int32),
        seg_offsets=offsets.astype(np.int32),
        seg_block=seg_block,
        num_edges=schedule.num_edges,
    )


def check_block_aligned(layout: BlockAlignedLayout, schedule: WaveSchedule) -> None:
    """Assert the block-aligned invariants (host-side, used by tests).

    * offsets are monotone, ``seg_block``-aligned, and end at the total;
    * every slot of the source schedule is covered exactly once, in the
      same wave-major order (the non-padding entries ARE ``order``);
    * padding rows appear only at the tail of each wave's tile run, so
      no tile straddles a wave boundary — the invariant that makes one
      tile op per trip race-free.
    """
    offs = layout.seg_offsets
    sb = layout.seg_block
    assert offs[0] == 0 and offs[-1] == layout.num_segments
    assert (np.diff(offs) >= 0).all(), "offsets must be monotone"
    assert (offs % sb == 0).all(), "offsets must be seg_block-aligned"
    flat = layout.slots.reshape(-1)
    live = flat[flat >= 0]
    assert np.array_equal(live, schedule.order), "slot coverage/order"
    counts = np.bincount(live, minlength=schedule.num_edges)
    assert counts.max(initial=0) <= 1, "a stream position occupies two slots"
    for k in range(schedule.num_waves):
        rows = layout.slots[offs[k] : offs[k + 1]]
        members = schedule.order[schedule.offsets[k] : schedule.offsets[k + 1]]
        rflat = rows.reshape(-1)
        assert (rflat[: len(members)] == members).all(), f"wave {k} layout"
        assert (rflat[len(members) :] == -1).all(), f"wave {k} padding"


def scatter_slot_assignments(slots, vals, m: int):
    """Scatter per-slot kernel outputs back to stream positions.

    ``slots`` int [..., W] maps slots to stream positions (-1 = padding),
    ``vals`` the matching per-slot assigned indices (>= -1). Returns
    int32 [m] with -1 for unscheduled edges. Padding slots alias position
    0 with value -1, so the max-scatter makes them exact no-ops. Safe
    inside jit (pure jnp).
    """
    import jax.numpy as jnp

    flat = slots.reshape(-1)
    vals = vals.reshape(-1)[: flat.shape[0]]
    live = flat >= 0
    return (
        jnp.full((m,), -1, jnp.int32)
        .at[jnp.where(live, flat, 0)]
        .max(jnp.where(live, vals, -1))
    )


def slot_arrays(schedule: WaveSchedule, src, dst, weight, valid=None):
    """Gather per-slot endpoint/weight arrays for vectorized consumers.

    Returns numpy ``(u, v, w, ok)``, each shaped [num_segments, SEG].
    Padding slots get ``u == v == 0`` and ``w == 0`` — below every
    substream threshold and a self-loop besides, so they can never match
    (the XLA wave engine relies on this encoding; the Pallas path remaps
    ``~ok`` slots to a sacrificial bit-block row before its in-place
    row scatter, see ops._waves_device).
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    weight = np.asarray(weight)
    slots = schedule.slots
    ok = slots >= 0
    if valid is not None:
        ok = ok & np.where(slots >= 0, np.asarray(valid, bool)[np.maximum(slots, 0)], False)
    safe = np.maximum(slots, 0)
    u = np.where(ok, src[safe], 0).astype(np.int32)
    v = np.where(ok, dst[safe], 0).astype(np.int32)
    w = np.where(ok, weight[safe], 0).astype(np.float32)
    return u, v, w, ok


def greedy_depths(src, dst, valid=None, order=None) -> np.ndarray:
    """Reference conflict depths (0-based), sequential oracle.

    ``depth[e] = 1 + max(depth of previous edge at u, at v)`` walked in
    processing order — the per-edge loop the vectorized scheduler
    replaced, kept as the test oracle for the "every edge is placed at
    or past its conflict depth" invariant. Returns int64 [m], -1 for
    unscheduled (invalid) edges.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    m = src.shape[0]
    valid_np = np.ones(m, bool) if valid is None else np.asarray(valid, bool)
    positions = np.arange(m) if order is None else np.asarray(order, dtype=np.int64)
    n_hint = int(max(src.max(), dst.max())) + 1 if m else 1
    last = np.full(n_hint, -1, np.int64)
    depth = np.full(m, -1, np.int64)
    for e in positions.tolist():
        if not valid_np[e]:
            continue
        u, v = src[e], dst[e]
        d = 1 + max(last[u], last[v])
        depth[e] = d
        last[u] = d
        last[v] = d
    return depth


def check_schedule(schedule: WaveSchedule, src, dst, valid=None, order=None) -> None:
    """Assert the wave invariants (used by tests; cheap, host-side).

    * every scheduled wave is vertex-disjoint (self-loops use one slot);
    * conflicting edges appear in processing order across waves
      (``order`` is the explicit permutation the schedule was built
      with, if any — stream order otherwise);
    * every edge sits at or past its conflict depth (equal when the
      schedule is uncapped);
    * ``order``/``offsets``/``seg_offsets``/``slots`` describe the same
      fill-packed decomposition: wave k's members fill its segment rows
      back-to-back with padding only at the tail of its last row.
    """
    src = np.asarray(src)
    dst = np.asarray(dst)
    wave = schedule.wave
    if valid is not None:
        valid = np.asarray(valid, bool)
        assert (wave[~valid] == -1).all(), "padding edges must be unscheduled"
        assert (wave[valid] >= 0).all(), "valid edges must be scheduled"
    seg = schedule.width
    for k in range(schedule.num_waves):
        members = schedule.order[schedule.offsets[k] : schedule.offsets[k + 1]]
        assert (wave[members] == k).all()
        verts = []
        for e in members.tolist():
            verts.append(src[e])
            if dst[e] != src[e]:
                verts.append(dst[e])
        assert len(verts) == len(set(verts)), f"wave {k} not vertex-disjoint"
        rows = schedule.slots[schedule.seg_offsets[k] : schedule.seg_offsets[k + 1]]
        flat = rows.reshape(-1)
        assert rows.shape[0] == -(-len(members) // seg), f"wave {k} segment count"
        assert (flat[: len(members)] == members).all(), f"wave {k} slot layout"
        assert (flat[len(members) :] == -1).all(), f"wave {k} slot padding"
    # depth floor: earliest-fit never places an edge before its conflict
    # depth (uncapped scheduling places it exactly there)
    depths = greedy_depths(src, dst, valid=valid, order=order)
    scheduled = wave >= 0
    assert (wave[scheduled] >= depths[scheduled]).all(), "edge above its depth"
    # order preservation among conflicting edges (in processing order)
    positions = (
        np.nonzero(scheduled)[0]
        if order is None
        else np.asarray(order)[wave[np.asarray(order)] >= 0]
    )
    touch: dict[int, int] = {}
    for e in positions.tolist():
        for x in {int(src[e]), int(dst[e])}:
            if x in touch:
                assert wave[touch[x]] < wave[e], (
                    f"edges {touch[x]} and {e} share vertex {x} but waves "
                    f"{wave[touch[x]]} >= {wave[e]}"
                )
            touch[x] = e
