"""Segment-op message passing — the GNN primitive (JAX has BCOO only, so
message passing is gather -> transform -> segment-reduce, per the brief)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def segment_sum(data, segment_ids, num_segments: int):
    return jax.ops.segment_sum(data, segment_ids, num_segments=num_segments)


def segment_max(data, segment_ids, num_segments: int):
    return jax.ops.segment_max(data, segment_ids, num_segments=num_segments)


def segment_mean(data, segment_ids, num_segments: int):
    s = segment_sum(data, segment_ids, num_segments)
    cnt = segment_sum(jnp.ones((data.shape[0],), data.dtype), segment_ids, num_segments)
    return s / jnp.maximum(cnt, 1.0)[(...,) + (None,) * (data.ndim - 1)]


def segment_softmax(scores, segment_ids, num_segments: int):
    """Edge-softmax (GAT-style): softmax of `scores` within each segment."""
    mx = segment_max(scores, segment_ids, num_segments)
    ex = jnp.exp(scores - mx[segment_ids])
    z = segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(z[segment_ids], 1e-9)


def scatter_messages(node_feats, src, dst, num_nodes: int, reduce: str = "sum"):
    """h'_v = reduce_{(u,v) in E} h_u — plain message passing."""
    msgs = node_feats[src]
    if reduce == "sum":
        return segment_sum(msgs, dst, num_nodes)
    if reduce == "mean":
        return segment_mean(msgs, dst, num_nodes)
    if reduce == "max":
        return segment_max(msgs, dst, num_nodes)
    raise ValueError(reduce)


def degrees(src, dst, num_nodes: int):
    ones = jnp.ones_like(src, dtype=jnp.float32)
    return segment_sum(ones, src, num_nodes) + segment_sum(ones, dst, num_nodes)
