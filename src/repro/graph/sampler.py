"""Neighbor sampler for sampled-training GNN shapes (minibatch_lg: 15-10).

A *real* fanout sampler over CSR (GraphSAGE-style): given seed nodes,
uniformly sample up to `fanout[h]` neighbors per node per hop, building the
block (bipartite layer) structure used by the models. Padded to static
shapes (required under jit); pad edges point at a dedicated sink node whose
features are zero and whose messages are masked by `edge_mask`.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class SampledBlock:
    """One hop: edges from sampled srcs (layer h+1 nodes) into dsts (layer h)."""

    src_index: np.ndarray  # int32 [E_pad]  — indices into this block's node table
    dst_index: np.ndarray  # int32 [E_pad]
    edge_mask: np.ndarray  # bool  [E_pad]
    nodes: np.ndarray  # int64 [N_pad] — global node ids of the block's inputs
    node_mask: np.ndarray  # bool [N_pad]
    num_dst: int


class NeighborSampler:
    def __init__(self, csr: CSRGraph, fanouts: Sequence[int], seed: int = 0):
        self.csr = csr
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)

    def sample(self, seeds: np.ndarray) -> list[SampledBlock]:
        """Returns one SampledBlock per hop, innermost (seeds) first."""
        blocks: list[SampledBlock] = []
        frontier = np.asarray(seeds, np.int64)
        for fanout in self.fanouts:
            nd = frontier.shape[0]
            e_pad = nd * fanout
            srcs = np.zeros(e_pad, np.int64)
            dsts = np.repeat(np.arange(nd, dtype=np.int32), fanout)
            mask = np.zeros(e_pad, bool)
            for i, u in enumerate(frontier):
                nbrs, _ = self.csr.neighbors(int(u))
                if nbrs.shape[0] == 0:
                    continue
                k = min(fanout, nbrs.shape[0])
                pick = self.rng.choice(nbrs, size=k, replace=nbrs.shape[0] < k)
                srcs[i * fanout : i * fanout + k] = pick
                mask[i * fanout : i * fanout + k] = True
            # unique node table: dst nodes first (self features), then srcs
            nodes, inv = np.unique(
                np.concatenate([frontier, srcs[mask]]), return_inverse=True
            )
            remap = {g: j for j, g in enumerate(nodes)}
            src_idx = np.array(
                [remap[g] if ok else len(nodes) for g, ok in zip(srcs, mask)],
                np.int32,
            )
            n_pad = len(nodes) + 1  # +1 sink row for masked edges
            node_tab = np.concatenate([nodes, [0]])
            node_mask = np.concatenate([np.ones(len(nodes), bool), [False]])
            blocks.append(
                SampledBlock(
                    src_index=src_idx,
                    dst_index=dsts,
                    edge_mask=mask,
                    nodes=node_tab,
                    node_mask=node_mask,
                    num_dst=nd,
                )
            )
            frontier = nodes  # next hop expands every block node
        return blocks
