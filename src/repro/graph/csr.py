"""CSR representations, including the paper's custom §4.3 layout.

`CSRGraph` is the working in-memory format (numpy). `CustomCSR` is a
byte-accurate implementation of the paper's DRAM layout:

  * 512-bit data chunks;
  * pointer_data: one 96-bit entry per adjacency row =
      (chunk_id u32, chunk_offset u32, num_edges u32); five entries per
      chunk (480 bits used, 32 padding);
  * graph_data: 64-bit edge entries = (col_index u32, weight f32/u32);
      eight edges per chunk.

The FPGA streams chunks; on TPU the same layout defines the HBM-resident
stream the kernel's BlockSpec pipeline walks, and the chunk accounting is
what the fig-level benchmarks use to model DRAM traffic (§5.11).
"""
from __future__ import annotations

import dataclasses

import numpy as np

CHUNK_BYTES = 64  # 512 bits
PTR_ENTRY_BYTES = 12  # 96 bits
PTRS_PER_CHUNK = 5  # 5 * 96 = 480 bits used per chunk
EDGE_ENTRY_BYTES = 8  # 64 bits
EDGES_PER_CHUNK = 8


@dataclasses.dataclass
class CSRGraph:
    """Standard CSR of an undirected weighted graph (both directions stored)."""

    row: np.ndarray  # int64 [n+1]
    col: np.ndarray  # int32 [m]
    val: np.ndarray  # float32 [m]

    @property
    def n(self) -> int:
        return self.row.shape[0] - 1

    @property
    def m(self) -> int:
        return self.col.shape[0]

    @staticmethod
    def from_edges(src, dst, weight, n: int, symmetrize: bool = False) -> "CSRGraph":
        src = np.asarray(src, np.int64)
        dst = np.asarray(dst, np.int64)
        weight = np.asarray(weight, np.float32)
        if symmetrize:
            src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
            weight = np.concatenate([weight, weight])
        order = np.lexsort((dst, src))
        src, dst, weight = src[order], dst[order], weight[order]
        row = np.zeros(n + 1, np.int64)
        np.add.at(row, src + 1, 1)
        row = np.cumsum(row)
        return CSRGraph(row=row, col=dst.astype(np.int32), val=weight)

    def to_stream_arrays(self):
        """(src, dst, weight) in CSR row-major order — the paper's stream order."""
        src = np.repeat(np.arange(self.n, dtype=np.int64), np.diff(self.row))
        return src, self.col.astype(np.int64), self.val

    def neighbors(self, u: int):
        s, e = self.row[u], self.row[u + 1]
        return self.col[s:e], self.val[s:e]


@dataclasses.dataclass
class CustomCSR:
    """The paper's custom CSR (§4.3), byte-accurate."""

    pointer_data: np.ndarray  # uint8 [ptr_chunks * 64]
    graph_data: np.ndarray  # uint8 [edge_chunks * 64]
    n: int
    m: int

    @staticmethod
    def encode(csr: CSRGraph) -> "CustomCSR":
        n, m = csr.n, csr.m
        # --- pointer_data ---
        ptr_chunks = (n + PTRS_PER_CHUNK - 1) // PTRS_PER_CHUNK
        pbuf = np.zeros(ptr_chunks * CHUNK_BYTES, np.uint8)
        counts = np.diff(csr.row).astype(np.uint32)
        starts = csr.row[:-1].astype(np.uint64)
        chunk_id = (starts // EDGES_PER_CHUNK).astype(np.uint32)
        chunk_off = (starts % EDGES_PER_CHUNK).astype(np.uint32)
        entry = np.zeros((n, 3), np.uint32)
        entry[:, 0] = chunk_id
        entry[:, 1] = chunk_off
        entry[:, 2] = counts
        ebytes = entry.view(np.uint8).reshape(n, PTR_ENTRY_BYTES)
        for i in range(n):
            c, slot = divmod(i, PTRS_PER_CHUNK)
            off = c * CHUNK_BYTES + slot * PTR_ENTRY_BYTES
            pbuf[off : off + PTR_ENTRY_BYTES] = ebytes[i]
        # --- graph_data ---
        edge_chunks = (m + EDGES_PER_CHUNK - 1) // EDGES_PER_CHUNK
        gbuf = np.zeros(edge_chunks * CHUNK_BYTES, np.uint8)
        ent = np.zeros((m, 2), np.uint32)
        ent[:, 0] = csr.col.astype(np.uint32)
        ent[:, 1] = csr.val.view(np.uint32) if csr.val.dtype == np.float32 else csr.val
        gbuf[: m * EDGE_ENTRY_BYTES] = ent.view(np.uint8).reshape(-1)[: m * EDGE_ENTRY_BYTES]
        return CustomCSR(pointer_data=pbuf, graph_data=gbuf, n=n, m=m)

    def decode(self) -> CSRGraph:
        n, m = self.n, self.m
        row = np.zeros(n + 1, np.int64)
        col = np.zeros(m, np.int32)
        val = np.zeros(m, np.float32)
        for i in range(n):
            c, slot = divmod(i, PTRS_PER_CHUNK)
            off = c * CHUNK_BYTES + slot * PTR_ENTRY_BYTES
            e = self.pointer_data[off : off + PTR_ENTRY_BYTES].view(np.uint32)
            start = int(e[0]) * EDGES_PER_CHUNK + int(e[1])
            row[i] = start
            row[i + 1] = start + int(e[2])
        ent = self.graph_data[: m * EDGE_ENTRY_BYTES].view(np.uint32).reshape(m, 2)
        col[:] = ent[:, 0]
        val[:] = ent[:, 1].view(np.float32)
        return CSRGraph(row=row, col=col, val=val)

    @property
    def dram_bytes(self) -> int:
        return self.pointer_data.nbytes + self.graph_data.nbytes

    def read_requests_per_edge(self) -> float:
        """§5.11 model: 1/8 chunk per edge (8 edges/chunk) + 1 matching-bit
        chunk per edge worst-case = 1.125 requests/edge."""
        return 1.0 + 1.0 / EDGES_PER_CHUNK
