"""Graph generators.

The paper evaluates on DIMACS-10 Kronecker power-law graphs (m ~= 48n) and
KONECT/SNAP real-world graphs. The container is offline, so real graphs
are replaced by RMAT standins with matched (n, m) — the same generator
family DIMACS uses — and weights are drawn uniformly from
[1, (1+eps)^(L-1)+1] with a fixed seed, exactly as §5.1.4.
"""
from __future__ import annotations

import numpy as np

# (n, m) of the paper's Table 5 datasets, for standin generation.
PAPER_GRAPHS = {
    "gowalla": (196_591, 950_327),
    "flickr": (2_302_925, 33_140_017),
    "livejournal1": (4_847_571, 68_993_773),
    "orkut": (3_072_441, 117_184_899),
    "stanford": (281_903, 2_312_497),
    "berkeley": (685_230, 7_600_595),
    "arxiv-hep-th": (27_770, 352_807),
}


def kronecker_graph(
    scale: int,
    edge_factor: int = 48,
    seed: int = 0,
    a: float = 0.57,
    b: float = 0.19,
    c: float = 0.19,
):
    """RMAT/Kronecker generator (Graph500 parameters; DIMACS-10 family).

    Returns (src, dst) int64 arrays with self-loops and duplicates removed
    (duplicates are removed to keep exact-oracle comparisons clean; the
    matcher itself tolerates both).
    """
    n = 1 << scale
    m = edge_factor * n
    rng = np.random.default_rng(seed)
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    ab, abc = a + b, a + b + c
    for bit in range(scale):
        r = rng.random(m)
        go_right = r > ab  # bottom half for source
        r2 = rng.random(m)
        thresh = np.where(go_right, c / (c + (1 - abc)), a / ab)
        go_down = r2 > thresh
        src |= go_right.astype(np.int64) << bit
        dst |= go_down.astype(np.int64) << bit
    keep = src != dst
    src, dst = src[keep], dst[keep]
    # canonicalize + dedupe
    lo = np.minimum(src, dst)
    hi = np.maximum(src, dst)
    key = lo * n + hi
    _, uniq = np.unique(key, return_index=True)
    uniq.sort()
    return src[uniq], dst[uniq]


def real_graph_standin(name: str, seed: int = 0, max_edges: int | None = None):
    """RMAT standin matched to a paper dataset's (n, m). See module note."""
    n, m = PAPER_GRAPHS[name]
    scale = int(np.ceil(np.log2(n)))
    ef = max(1, int(round(m / (1 << scale))))
    src, dst = kronecker_graph(scale, edge_factor=ef, seed=seed)
    if max_edges is not None and src.shape[0] > max_edges:
        src, dst = src[:max_edges], dst[:max_edges]
    return src, dst


def uniform_weights(m: int, L: int, eps: float, seed: int = 0) -> np.ndarray:
    """Weights uniform in [1, (1+eps)^(L-1) + 1] with fixed seed (§5.1.4)."""
    rng = np.random.default_rng(seed)
    hi = (1.0 + eps) ** (L - 1) + 1.0
    return rng.uniform(1.0, hi, m).astype(np.float32)
