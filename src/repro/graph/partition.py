"""Partitioners for distributed matching / GNN training."""
from __future__ import annotations

import numpy as np


def partition_edges(src, dst, weight, num_parts: int, pad_to_multiple: int = 8):
    """Round-robin-free contiguous edge partition preserving stream order.

    Returns (src_p, dst_p, w_p, valid_p) each shaped [num_parts, m_part] so
    they can be fed to shard_map over the data axis; stream priority is
    (part * m_part + local_idx), matching repro.core.rounds' convention.
    """
    m = len(src)
    m_part = -(-m // num_parts)
    m_part = -(-m_part // pad_to_multiple) * pad_to_multiple
    tot = m_part * num_parts
    pad = tot - m

    def padcat(x, fill=0, dtype=None):
        x = np.asarray(x)
        out = np.concatenate([x, np.full(pad, fill, x.dtype if dtype is None else dtype)])
        return out.reshape(num_parts, m_part)

    valid = np.concatenate([np.ones(m, bool), np.zeros(pad, bool)]).reshape(
        num_parts, m_part
    )
    return padcat(src), padcat(dst), padcat(weight, 0.0), valid


def partition_vertices(n: int, num_parts: int):
    """Contiguous vertex ranges [start, end) per part."""
    step = -(-n // num_parts)
    return [(p * step, min(n, (p + 1) * step)) for p in range(num_parts)]
