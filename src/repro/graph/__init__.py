"""Graph substrate: generators, CSR (incl. the paper's custom layout),
segment-op message passing, neighbor sampling, partitioning, coarsening."""
from repro.graph.generators import kronecker_graph, uniform_weights, real_graph_standin
from repro.graph.csr import CSRGraph, CustomCSR
from repro.graph.segment import (
    segment_sum,
    segment_mean,
    segment_max,
    segment_softmax,
    scatter_messages,
    degrees,
)
from repro.graph.sampler import NeighborSampler
from repro.graph.partition import partition_edges, partition_vertices
from repro.graph.coarsen import coarsen_by_matching
from repro.graph.waves import WaveSchedule, wave_schedule

__all__ = [
    "kronecker_graph",
    "uniform_weights",
    "real_graph_standin",
    "CSRGraph",
    "CustomCSR",
    "segment_sum",
    "segment_mean",
    "segment_max",
    "segment_softmax",
    "scatter_messages",
    "degrees",
    "NeighborSampler",
    "partition_edges",
    "partition_vertices",
    "coarsen_by_matching",
    "WaveSchedule",
    "wave_schedule",
]
