"""Deterministic, restartable data pipelines.

Every pipeline is seeded and indexed by *global step*, so restart-from-
checkpoint resumes the exact batch sequence (fault tolerance requirement:
data state is derived, never stored). Synthetic sources stand in for real
corpora (offline container), but the sharding/feeding structure is the
production one: each host materializes only its shard and device_puts with
the step's sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.generators import kronecker_graph, uniform_weights
from repro.models.gnn_common import GraphBatch


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0

    def batch_at(self, step: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, step))
        # zipf-ish synthetic tokens — nontrivial unigram distribution so
        # the loss actually decreases
        z = rng.zipf(1.3, size=(self.batch, self.seq_len))
        return (z % self.vocab).astype(np.int32)


@dataclasses.dataclass
class GraphStreamPipeline:
    """Streams a Kronecker graph's edges in epoch blocks (paper workload)."""

    scale: int
    edge_factor: int
    L: int
    eps: float
    seed: int = 0

    def build(self):
        src, dst = kronecker_graph(self.scale, self.edge_factor, self.seed)
        w = uniform_weights(len(src), self.L, self.eps, self.seed)
        n = 1 << self.scale
        csr = CSRGraph.from_edges(src, dst, w, n=n, symmetrize=False)
        return csr

    def stream(self):
        csr = self.build()
        return csr.to_stream_arrays()


def make_gnn_batch(
    n_nodes: int,
    n_edges: int,
    d_feat: int,
    *,
    n_classes: int = 0,
    d_out: int = 0,
    coords: bool = False,
    n_graphs: int = 0,
    seed: int = 0,
) -> GraphBatch:
    """Synthetic GraphBatch with valid masks (connected-ish random graph)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges)
    dst = rng.integers(0, n_nodes, n_edges)
    ok = src != dst
    import jax.numpy as jnp

    if n_classes:
        labels = jnp.asarray(rng.integers(0, n_classes, n_nodes), jnp.int32)
    else:
        labels = jnp.asarray(rng.normal(size=(n_nodes, max(d_out, 1))), jnp.float32)
    gid = None
    if n_graphs:
        gid = jnp.asarray(
            np.repeat(np.arange(n_graphs), n_nodes // n_graphs), jnp.int32
        )
    return GraphBatch(
        node_feats=jnp.asarray(rng.normal(size=(n_nodes, d_feat)), jnp.float32),
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        edge_mask=jnp.asarray(ok),
        node_mask=jnp.ones(n_nodes, bool),
        coords=jnp.asarray(rng.normal(size=(n_nodes, 3)), jnp.float32) if coords else None,
        graph_ids=gid,
        labels=labels,
        label_mask=jnp.ones(n_nodes, bool),
    )


@dataclasses.dataclass
class RecsysPipeline:
    item_vocab: int
    batch: int
    seq_len: int
    n_mask: int
    n_negatives: int
    n_context: int = 16
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        import jax.numpy as jnp

        zipf = lambda size: (rng.zipf(1.2, size=size) % self.item_vocab).astype(np.int32)
        neg = zipf(self.n_negatives)
        # logQ for zipf(1.2) ~ -1.2 log(rank) - log(zeta); rough correction
        logq = (-1.2 * np.log1p(neg)).astype(np.float32)
        return {
            "item_ids": jnp.asarray(zipf((self.batch, self.seq_len))),
            "context_ids": jnp.asarray(zipf((self.batch, self.n_context))),
            "mask_pos": jnp.asarray(
                rng.integers(0, self.seq_len, (self.batch, self.n_mask)), np.int32
            ),
            "labels": jnp.asarray(zipf((self.batch, self.n_mask))),
            "negatives": jnp.asarray(neg),
            "neg_logq": jnp.asarray(logq),
        }
