from repro.data.pipeline import (
    TokenPipeline,
    GraphStreamPipeline,
    RecsysPipeline,
    make_gnn_batch,
)

__all__ = [
    "TokenPipeline",
    "GraphStreamPipeline",
    "RecsysPipeline",
    "make_gnn_batch",
]
