"""Straggler mitigation: step-time monitoring + policy hooks.

On a synchronous SPMD mesh a straggling host shows up as a slow global
step. The monitor tracks a per-step EWMA and flags outliers; the trainer
reacts per policy:
  * "warn"      — log only;
  * "skip_data" — drop the slow host's shard for the step (gradient is
                  rescaled by the surviving fraction);
  * "remesh"    — trigger the elastic path (distributed/elastic.py).

In this single-host container the monitor is exercised with injected
delays in ``tests/test_fault_tolerance.py``, and it watches per-epoch
times in the resumable executor (``repro.core.executor.ExecutionGuard``
emits ``guard.straggler`` telemetry events from its verdicts); the
policy machinery is identical on a real cluster where step times come
from the host-local clock.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ewma: float
    ratio: float


class StragglerMonitor:
    def __init__(self, alpha: float = 0.1, threshold: float = 2.0,
                 warmup_steps: int = 5, history: int = 100):
        self.alpha = alpha
        self.threshold = threshold
        self.warmup_steps = warmup_steps
        self.ewma: Optional[float] = None
        self.step = 0
        self.events: deque[StragglerEvent] = deque(maxlen=history)
        self._t0: Optional[float] = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> Optional[StragglerEvent]:
        assert self._t0 is not None, "start() not called"
        dt = time.monotonic() - self._t0
        self._t0 = None
        return self.observe(dt)

    def observe(self, step_time: float) -> Optional[StragglerEvent]:
        self.step += 1
        if self.ewma is None:
            self.ewma = step_time
            return None
        event = None
        ratio = step_time / max(self.ewma, 1e-9)
        if self.step > self.warmup_steps and ratio > self.threshold:
            event = StragglerEvent(self.step, step_time, self.ewma, ratio)
            self.events.append(event)
            # do not pollute the EWMA with the outlier
            return event
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return event
