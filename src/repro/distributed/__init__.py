from repro.distributed.sharding import (
    sharding_rules,
    constrain,
    current_rules,
)
from repro.distributed.straggler import StragglerMonitor
from repro.distributed.elastic import plan_remesh

__all__ = [
    "sharding_rules",
    "constrain",
    "current_rules",
    "StragglerMonitor",
    "plan_remesh",
]
