"""Elastic scaling: rebuild the mesh after membership changes and reshard.

Flow on a real cluster: a node dies -> the job restarts on the survivors
(or an enlarged pool) -> ``plan_remesh`` picks the largest valid mesh ->
the checkpoint (which stores *unsharded logical* arrays, see
repro.checkpoint) is restored with the new shardings. Nothing in the
checkpoint format depends on the old topology, which is what makes this
work. The degradation ladder (``plan_remesh`` across shrinking device
counts) and the host-device mesh rebuild are covered by
``tests/test_fault_tolerance.py``.
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class RemeshPlan:
    data: int
    model: int
    pod: int  # 0 -> no pod axis
    dropped_devices: int

    @property
    def n_devices(self) -> int:
        return max(self.pod, 1) * self.data * self.model


def plan_remesh(n_available: int, *, prefer_model: int = 16,
                min_model: int = 1) -> RemeshPlan:
    """Largest (data, model) mesh fitting n_available devices.

    Keeps the model axis as close to `prefer_model` as the pool allows
    (TP degree changes force weight resharding but stay legal for any
    divisor of the original), then maximizes data. Excess devices idle.
    """
    model = min(prefer_model, n_available)
    while model > min_model and n_available // model < 1:
        model //= 2
    # model axis must divide cleanly into the pool to keep SPMD rectangular
    while model > min_model and (n_available // model) * model < n_available * 0.5:
        model //= 2
    data = max(1, n_available // model)
    used = data * model
    return RemeshPlan(data=data, model=model, pod=0,
                      dropped_devices=n_available - used)


def build_mesh(plan: RemeshPlan, devices=None):
    devices = devices if devices is not None else jax.devices()
    devices = devices[: plan.n_devices]
    import numpy as np

    arr = np.asarray(devices).reshape(
        (plan.pod, plan.data, plan.model) if plan.pod else (plan.data, plan.model)
    )
    names = ("pod", "data", "model") if plan.pod else ("data", "model")
    return jax.sharding.Mesh(arr, names)
