"""Logical-axis sharding constraints for model internals.

Models call ``constrain(x, "expert", "dp", None)`` with *logical* names;
whether that becomes a real with_sharding_constraint depends on the rules
installed by the trainer/dry-run (``with sharding_rules(rules): ...``).
Smoke tests run with no rules installed -> constraints are no-ops, the same
model code runs on one CPU device.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def current_rules():
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def sharding_rules(rules: dict):
    prev = current_rules()
    _state.rules = rules
    try:
        yield
    finally:
        _state.rules = prev


def constrain(x, *logical):
    rules = current_rules()
    if rules is None:
        return x
    axes = []
    used = set()
    for name in logical:
        ax = rules.get(name) if name is not None else None
        key = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
        if ax is not None and any(k in used for k in key):
            ax = None
        if ax is not None:
            used.update(key)
        axes.append(ax)
    try:
        return jax.lax.with_sharding_constraint(x, P(*axes))
    except Exception:
        return x
