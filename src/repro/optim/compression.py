"""Gradient compression: int8 blockwise quantization with error feedback.

Used by the shard_map training path to compress the DP gradient exchange
(psum of int8 payloads + fp32 per-block scales), with residual error
carried to the next step (EF-SGD style, Karimireddy et al. 2019). A
distributed-optimization trick for the 1000-node regime where DCN
all-reduce bandwidth dominates.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_len(n: int) -> int:
    return (n + BLOCK - 1) // BLOCK * BLOCK


def compress_int8(x: jax.Array):
    """x -> (q int8 [n_pad], scale f32 [n_pad/BLOCK], shape)."""
    flat = x.astype(jnp.float32).reshape(-1)
    n = flat.shape[0]
    npad = _pad_len(n)
    flat = jnp.pad(flat, (0, npad - n))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    q = jnp.round(blocks / jnp.maximum(scale, 1e-12)[:, None]).astype(jnp.int8)
    return q, scale, x.shape


def decompress_int8(q, scale, shape, dtype=jnp.float32):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


@dataclasses.dataclass
class ErrorFeedback:
    """Stateless helpers; the residual lives in the caller's state tree."""

    @staticmethod
    def init(params):
        return jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

    @staticmethod
    def compress_with_feedback(grad, residual):
        """(grad, residual) -> (q, scale, shape, new_residual)."""
        corrected = grad.astype(jnp.float32) + residual
        q, scale, shape = compress_int8(corrected)
        recon = decompress_int8(q, scale, shape)
        return q, scale, shape, corrected - recon
