from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_init_specs,
    adamw_update,
    clip_by_global_norm,
)
from repro.optim.schedule import wsd_schedule, cosine_schedule
from repro.optim.compression import compress_int8, decompress_int8, ErrorFeedback

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_init_specs",
    "adamw_update",
    "clip_by_global_norm",
    "wsd_schedule",
    "cosine_schedule",
    "compress_int8",
    "decompress_int8",
    "ErrorFeedback",
]
