"""AdamW with bf16 params / fp32 moments, ZeRO-shardable state.

Moment tensors get the *same logical axes* as their parameters, so the
distribution layer can assign them more aggressive (ZeRO) sharding than the
params themselves — XLA then emits the reduce-scatter / all-gather pair of
ZeRO-1 automatically. ``moment_dtype=jnp.int8`` selects 8-bit block-quantized
moments (beyond-paper memory optimization, see EXPERIMENTS §Perf).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.param import ArraySpec


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32


def adamw_init_specs(param_spec_tree, cfg: AdamWConfig):
    def mom(s: ArraySpec) -> ArraySpec:
        return ArraySpec(s.shape, s.logical, cfg.moment_dtype, "zeros")

    is_leaf = lambda x: isinstance(x, ArraySpec)
    return {
        "m": jax.tree_util.tree_map(mom, param_spec_tree, is_leaf=is_leaf),
        "v": jax.tree_util.tree_map(mom, param_spec_tree, is_leaf=is_leaf),
        "count": ArraySpec((), (), jnp.int32, "zeros"),
    }


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def clip_by_global_norm(grads, max_norm: float):
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree_util.tree_leaves(grads)
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree_util.tree_map(lambda g: (g * scale).astype(g.dtype), grads), norm


def adamw_update(params, grads, opt_state, lr, cfg: AdamWConfig):
    """Returns (new_params, new_opt_state, grad_norm)."""
    if cfg.grad_clip:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = jnp.float32(0)
    count = opt_state["count"] + 1
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32)
        v32 = v.astype(jnp.float32)
        m_new = cfg.b1 * m32 + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v32 + (1 - cfg.b2) * g32 * g32
        step = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        p_new = p.astype(jnp.float32) - lr * (step + cfg.weight_decay * p.astype(jnp.float32))
        return (
            p_new.astype(p.dtype),
            m_new.astype(cfg.moment_dtype),
            v_new.astype(cfg.moment_dtype),
        )

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(opt_state["m"])
    flat_v = tdef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gnorm
