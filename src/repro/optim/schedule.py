"""LR schedules. WSD (warmup-stable-decay) is MiniCPM's contribution
(arXiv:2404.06395) and is wired as that arch's default."""
from __future__ import annotations

import jax.numpy as jnp


def wsd_schedule(step, peak_lr: float, warmup: int, stable: int, decay: int,
                 final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    in_decay = jnp.clip((step - warmup - stable) / jnp.maximum(decay, 1), 0.0, 1.0)
    decay_mult = jnp.exp(jnp.log(final_frac) * in_decay)  # exponential decay leg
    return jnp.where(step < warmup + stable, warm, peak_lr * decay_mult)


def cosine_schedule(step, peak_lr: float, warmup: int, total: int,
                    final_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, peak_lr * cos)
