"""Metrics registry — the quantities the plans compute but used to throw away.

A :class:`Counters` is a flat name → number registry with two write
modes: :meth:`add` accumulates (call counts, cache hits, bytes moved)
and :meth:`put` overwrites (gauges: fill, plan geometry). Names are
dotted, lowercase, and cataloged in ``docs/observability.md`` — e.g.
``schedule.num_waves``, ``plan.gather_bytes``, ``jit.variant_misses``.

Values are plain Python ints/floats copied bit-exactly from their
sources (``WavePlan``/``mega_plan`` accounting, ``WaveSchedule``
geometry), so tests can compare them ``==`` against a recomputed plan —
the registry never rounds or rescales.

The disabled path is :data:`NULL_COUNTERS`, a shared no-op instance;
like the null span it allocates nothing per call.

The module also owns the process-wide jit-variant ledger
(:func:`variant_seen`): engines key their compiled variants by
``(engine, seg, width, L, ...)`` and ask the ledger whether this call
is a first (compile) or repeat (execute) — tracked unconditionally
(one tuple hash per engine call) so that warm-up calls made with
telemetry disabled still count as warm when telemetry turns on.
"""
from __future__ import annotations


class Counters:
    """Flat metrics registry: dotted names → int/float values."""

    __slots__ = ("_vals",)

    def __init__(self):
        self._vals: dict[str, float] = {}

    def add(self, name: str, value=1):
        """Accumulate ``value`` onto ``name`` (missing counters start at 0)."""
        self._vals[name] = self._vals.get(name, 0) + value

    def put(self, name: str, value):
        """Set gauge ``name`` to exactly ``value`` (overwrites)."""
        self._vals[name] = value

    def get(self, name: str, default=0):
        return self._vals.get(name, default)

    def update(self, other: dict, prefix: str = ""):
        """Bulk :meth:`put` from a dict, optionally under ``prefix``."""
        for k, v in other.items():
            self._vals[prefix + k] = v

    def asdict(self) -> dict:
        """Plain sorted dict copy (JSON-ready)."""
        return {k: self._vals[k] for k in sorted(self._vals)}

    def __len__(self) -> int:
        return len(self._vals)

    def __repr__(self) -> str:
        return f"Counters({self._vals!r})"


class _NullCounters:
    """Shared no-op registry for the disabled path."""

    __slots__ = ()

    def add(self, name, value=1):
        pass

    def put(self, name, value):
        pass

    def get(self, name, default=0):
        return default

    def update(self, other, prefix=""):
        pass

    def asdict(self) -> dict:
        return {}

    def __len__(self) -> int:
        return 0


NULL_COUNTERS = _NullCounters()

#: Process-wide set of jit-variant keys already dispatched once.
_VARIANTS_SEEN: set = set()


def variant_seen(key) -> bool:
    """True if ``key`` was dispatched before in this process (a cache hit).

    First call for a key returns False (this call pays tracing +
    compilation) and marks it seen. Tracked even when telemetry is
    disabled so hit/miss labels stay truthful across enable/disable
    boundaries — the underlying ``jax.jit`` cache is process-wide too.
    """
    if key in _VARIANTS_SEEN:
        return True
    _VARIANTS_SEEN.add(key)
    return False
