"""Per-call telemetry records for the matching engines.

A :class:`MatchTelemetry` is the aggregate of ONE ``substream_match``
(or XLA-engine) call: which engine/backend actually ran, the host
stage split, the counter snapshot, and the derived rates. The stages:

``schedule``
    Host wave-schedule assignment (conflict-depth / earliest-fit), or —
    when a precomputed schedule was passed in — its validation cost.
``pack``
    Host fill-packed slot layout of a schedule built in-call (0.0 when
    the schedule was precomputed).
``layout``
    Host per-call stream prep: block-aligned re-padding (mega), slot
    array gather, grid padding, and the slot→stream scatter-back.
``compile``
    Wall time of the device call when its jit variant — keyed by
    ``(engine, seg, width, L, shapes, ...)`` — was dispatched for the
    first time in this process. Dominated by tracing + XLA compilation
    but *includes the first execution* (JAX offers no portable split of
    the two inside one dispatch); steady-state calls report 0 here.
``execute``
    Wall time (``block_until_ready``) of the device call when the
    variant was already compiled; 0 on the compile call.

Stage seconds are disjoint wall-clock intervals of the same call, so
``sum(stage_seconds.values()) <= wall_seconds`` always — checked by
:func:`consistency_problems`, which the bench gate reuses.

Engines build records through :func:`recorder`; its disabled twin
(:data:`NULL_RECORDER`) makes every instrumentation site a no-op when
telemetry is off.
"""
from __future__ import annotations

import dataclasses
import time

from repro.obs.counters import variant_seen
from repro.obs.trace import NULL_SPAN

#: The canonical stage keys, in pipeline order. Every MatchTelemetry
#: (and every bench ``stage_seconds`` row) carries exactly these.
STAGES = ("schedule", "pack", "layout", "compile", "execute")

#: Counter names every wave/mega engine record must carry (the plan
#: accounting the bench gate cross-checks bit-exactly).
PLAN_COUNTERS = ("plan.gather_bytes", "plan.bit_block_bytes")


@dataclasses.dataclass(frozen=True)
class MatchTelemetry:
    """Aggregated telemetry of one matching-engine call."""

    engine: str
    backend: str
    interpret: bool
    num_edges: int
    wall_seconds: float
    stage_seconds: dict
    counters: dict

    @property
    def edges_per_sec(self) -> float:
        """Full-call rate (host + device) — the number the bench reports."""
        return self.num_edges / self.wall_seconds if self.wall_seconds > 0 else 0.0

    @property
    def device_seconds(self) -> float:
        return self.stage_seconds.get("compile", 0.0) + self.stage_seconds.get(
            "execute", 0.0
        )

    def roofline(self) -> dict:
        """Achieved-vs-bound fraction via :mod:`repro.launch.roofline`.

        Uses the per-edge HBM traffic implied by the counters
        (``traffic.hbm_bytes`` over the stream length) against the
        pipeline/memory bound of the substream kernel model. Returns
        the bound terms plus ``achieved_fraction``.
        """
        from repro.launch import roofline as _roofline

        nbytes = self.counters.get("traffic.hbm_bytes", 0)
        bpe = nbytes / self.num_edges if self.num_edges else 0.0
        return _roofline.substream_achieved(self.edges_per_sec, bpe)

    def asdict(self) -> dict:
        """JSON-ready dict (stages in canonical order, sorted counters)."""
        return {
            "engine": self.engine,
            "backend": self.backend,
            "interpret": self.interpret,
            "num_edges": self.num_edges,
            "wall_seconds": self.wall_seconds,
            "edges_per_sec": self.edges_per_sec,
            "stage_seconds": {s: self.stage_seconds.get(s, 0.0) for s in STAGES},
            "counters": {k: self.counters[k] for k in sorted(self.counters)},
        }


def consistency_problems(
    stage_seconds: dict, wall_seconds: float, rel_slack: float = 0.02,
    abs_slack: float = 1e-4,
) -> list[str]:
    """Internal-consistency check shared by tests and the bench gate.

    Returns human-readable problem strings (empty = consistent):
    missing stage keys, negative stages, or stage sums exceeding the
    call's wall time beyond slack (stages are disjoint sub-intervals of
    the wall interval, so their sum can never legitimately exceed it).
    """
    problems = []
    missing = [s for s in STAGES if s not in stage_seconds]
    if missing:
        problems.append(f"missing stage keys {missing}")
    negative = {s: v for s, v in stage_seconds.items() if v < 0}
    if negative:
        problems.append(f"negative stage seconds {negative}")
    total = sum(v for v in stage_seconds.values() if v > 0)
    if total > wall_seconds * (1 + rel_slack) + abs_slack:
        problems.append(
            f"stage sum {total:.6f}s exceeds wall {wall_seconds:.6f}s"
        )
    return problems


class _StageSpan:
    """Context manager crediting its duration to one recorder stage."""

    __slots__ = ("_rec", "_stage", "_t0")

    def __init__(self, rec: "MatchRecorder", stage: str):
        self._rec = rec
        self._stage = stage

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        rec = self._rec
        rec.stage_seconds[self._stage] += t1 - self._t0
        rec._telemetry.tracer.complete(
            f"{rec.engine}.{self._stage}", self._t0, t1
        )
        return False


class MatchRecorder:
    """Accumulates one engine call's stages/counters into a record.

    Created via :func:`recorder` at engine entry; ``finish()`` seals
    the record, appends it to ``telemetry.match_calls``, and folds the
    session-level aggregates (call counts, jit hit/miss totals) into
    the telemetry counter registry.
    """

    __slots__ = (
        "_telemetry", "engine", "backend", "interpret", "num_edges",
        "stage_seconds", "counters", "_t0",
    )

    def __init__(self, telemetry, engine, num_edges, backend, interpret):
        self._telemetry = telemetry
        self.engine = engine
        self.backend = backend
        self.interpret = interpret
        self.num_edges = num_edges
        self.stage_seconds = dict.fromkeys(STAGES, 0.0)
        self.counters: dict = {}
        self._t0 = time.perf_counter()

    def stage(self, name: str) -> _StageSpan:
        """``with rec.stage("layout"): ...`` — credit the block to a stage."""
        return _StageSpan(self, name)

    def device_stage(self, variant_key) -> _StageSpan:
        """Stage for the jitted device call: ``compile`` on the variant's
        first dispatch in this process, ``execute`` on repeats; also
        bumps the ``jit.variant_hit``/``jit.variant_miss`` counters."""
        hit = variant_seen(variant_key)
        self.count("jit.variant_hit" if hit else "jit.variant_miss")
        return self.stage("execute" if hit else "compile")

    def add_stage(self, name: str, seconds: float):
        """Credit pre-measured seconds to a stage (e.g. the schedule /
        pack timings a :class:`~repro.graph.waves.WaveSchedule` already
        carries from its one ``obs.stopwatch`` timing path)."""
        self.stage_seconds[name] += seconds

    def count(self, name: str, value=1):
        self.counters[name] = self.counters.get(name, 0) + value

    def put(self, name: str, value):
        self.counters[name] = value

    def put_many(self, values: dict, prefix: str = ""):
        for k, v in values.items():
            self.counters[prefix + k] = v

    def block(self, out):
        """``jax.block_until_ready`` so device time lands in the open
        stage — only ever called on the enabled path."""
        import jax

        jax.block_until_ready(out)
        return out

    def finish(self) -> MatchTelemetry:
        wall = time.perf_counter() - self._t0
        record = MatchTelemetry(
            engine=self.engine,
            backend=self.backend,
            interpret=self.interpret,
            num_edges=self.num_edges,
            wall_seconds=wall,
            stage_seconds=dict(self.stage_seconds),
            counters=dict(self.counters),
        )
        tel = self._telemetry
        tel.match_calls.append(record)
        tel.counters.add("substream_match.calls")
        tel.counters.add("jit.variant_hits", self.counters.get("jit.variant_hit", 0))
        tel.counters.add(
            "jit.variant_misses", self.counters.get("jit.variant_miss", 0)
        )
        tel.counters.update(record.counters, prefix=f"{self.engine}.")
        return record


class _NullRecorder:
    """Shared no-op recorder — the entire disabled instrumentation path."""

    __slots__ = ()

    def stage(self, name):
        return NULL_SPAN

    def device_stage(self, variant_key):
        # keep the process-wide ledger truthful even when disabled: a
        # warm-up call with telemetry off must count as warm later
        variant_seen(variant_key)
        return NULL_SPAN

    def add_stage(self, name, seconds):
        pass

    def count(self, name, value=1):
        pass

    def put(self, name, value):
        pass

    def put_many(self, values, prefix=""):
        pass

    def block(self, out):
        return out

    def finish(self):
        return None


NULL_RECORDER = _NullRecorder()


def recorder(
    telemetry, engine: str, num_edges: int, backend: str = "", interpret: bool = False
):
    """A :class:`MatchRecorder` when telemetry is enabled, else the
    shared no-op recorder. The single entry engines instrument through."""
    if telemetry is None or not telemetry.enabled:
        return NULL_RECORDER
    return MatchRecorder(telemetry, engine, num_edges, backend, interpret)
