"""Telemetry for the substream matching stack (zero-overhead when disabled).

Three parts (see ``docs/observability.md`` for the span/counter
catalog):

* :mod:`repro.obs.trace` — nesting span tracer on ``perf_counter``
  with Chrome trace-event JSON export (open in Perfetto);
* :mod:`repro.obs.counters` — flat metrics registry for the plan /
  schedule quantities the engines already compute;
* :mod:`repro.obs.report` — :class:`MatchTelemetry`, the per-call
  aggregate (stage split, counters, derived rates, roofline fraction).

Usage::

    from repro import obs

    tel = obs.Telemetry()
    result = substream_match(stream, cfg, schedule="mega", telemetry=tel)
    print(tel.match_calls[-1].stage_seconds)     # schedule/pack/layout/...
    tel.write_chrome_trace("trace.json")          # -> ui.perfetto.dev

Every instrumented entry point takes ``telemetry=obs.DISABLED`` by
default. The disabled facade is one shared object whose ``span()``
returns one shared no-op context manager and whose counter calls do
nothing — engines call it unconditionally from hot paths without
allocating or branching beyond a method dispatch.
"""
from __future__ import annotations

from repro.obs.counters import NULL_COUNTERS, Counters, variant_seen
from repro.obs.report import (
    STAGES,
    MatchTelemetry,
    NULL_RECORDER,
    consistency_problems,
    recorder,
)
from repro.obs.trace import NULL_SPAN, Span, Tracer, stopwatch

__all__ = [
    "Telemetry",
    "DISABLED",
    "Tracer",
    "Span",
    "Counters",
    "MatchTelemetry",
    "STAGES",
    "stopwatch",
    "recorder",
    "consistency_problems",
    "variant_seen",
    "NULL_SPAN",
    "NULL_COUNTERS",
    "NULL_RECORDER",
]


class Telemetry:
    """Enabled telemetry session: one tracer + one counter registry.

    ``match_calls`` collects the :class:`MatchTelemetry` record of every
    instrumented engine call made with this session; ``events`` holds
    the structured instant events (e.g. ``substream_match.backend``)
    in arrival order, mirrored into the trace as instant marks.
    """

    enabled = True

    def __init__(self):
        self.tracer = Tracer()
        self.counters = Counters()
        self.match_calls: list[MatchTelemetry] = []
        self.events: list[dict] = []

    def span(self, name: str, **args):
        """Nesting span context manager (recorded on exit)."""
        return self.tracer.span(name, **args)

    def count(self, name: str, value=1):
        self.counters.add(name, value)

    def event(self, name: str, **args):
        """Structured instant event: kept in ``events`` + the trace."""
        self.events.append({"name": name, **args})
        self.tracer.instant(name, **args)

    def chrome_trace(self) -> dict:
        return self.tracer.chrome_trace(metadata={"counters": self.counters.asdict()})

    def write_chrome_trace(self, path) -> None:
        """Write the session trace to ``path`` (Chrome trace-event JSON)."""
        self.tracer.write_chrome_trace(
            path, metadata={"counters": self.counters.asdict()}
        )


class _DisabledTelemetry:
    """The shared no-op telemetry facade (:data:`DISABLED`).

    Identity-stable: ``DISABLED.span(...)`` returns the one module-level
    :data:`NULL_SPAN` object every time, counters route to
    :data:`NULL_COUNTERS`, and nothing is ever recorded. ``match_calls``
    and ``events`` are shared empty tuples so accidental reads are safe
    and accidental writes fail loudly.
    """

    enabled = False
    counters = NULL_COUNTERS
    match_calls = ()
    events = ()

    __slots__ = ()

    def span(self, name, **args):
        return NULL_SPAN

    def count(self, name, value=1):
        pass

    def event(self, name, **args):
        pass

    def chrome_trace(self) -> dict:
        return {"traceEvents": [], "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path) -> None:
        raise RuntimeError(
            "telemetry is disabled; construct repro.obs.Telemetry() and pass "
            "it via telemetry= to record a trace"
        )


DISABLED = _DisabledTelemetry()
