"""Nesting span tracer with Chrome trace-event JSON export.

The tracer is the timing half of :mod:`repro.obs`: ``with
tracer.span("pack"): ...`` records one *complete* event per exit on a
single ``perf_counter`` timebase, and :meth:`Tracer.chrome_trace`
serializes the session as Chrome trace-event JSON — the format Perfetto
(https://ui.perfetto.dev) and ``chrome://tracing`` open directly.
Nesting is positional, exactly like Chrome's own traces: an event is a
child of whichever event's ``[ts, ts + dur]`` interval encloses it on
the same track, so the tracer needs no explicit stack.

Zero-overhead-when-disabled contract
------------------------------------
The disabled path never touches this module's classes: ``NULL_SPAN`` is
one shared, reentrant no-op context manager and the disabled telemetry
facade returns it by identity from every ``span()`` call — no event
list, no timestamping, no per-call object. Hot loops may call
``telemetry.span(...)`` unconditionally.

:class:`stopwatch` is the single timing path shared by code that must
report a duration even when telemetry is off (e.g. the deprecated
``WaveSchedule.schedule_seconds`` compatibility fields): it always
measures ``perf_counter`` and *additionally* records a span when the
telemetry object is enabled, so there is one measurement, two views.
"""
from __future__ import annotations

import json
import threading
import time


class _NullSpan:
    """Shared no-op context manager — the entire disabled span path.

    A single module-level instance (:data:`NULL_SPAN`) is returned for
    every disabled ``span()`` call; it is stateless, reentrant, and
    allocation-free on entry/exit.
    """

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


NULL_SPAN = _NullSpan()


class Span:
    """One live span of an enabled :class:`Tracer` (context manager).

    Timestamps are taken on ``__enter__``/``__exit__``; the completed
    event is appended to the owning tracer at exit. ``seconds`` holds
    the duration after exit (also exposed by :class:`stopwatch`).
    """

    __slots__ = ("_tracer", "name", "args", "t0", "seconds")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None):
        self._tracer = tracer
        self.name = name
        self.args = args
        self.t0 = 0.0
        self.seconds = 0.0

    def __enter__(self) -> "Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self.seconds = t1 - self.t0
        self._tracer.complete(self.name, self.t0, t1, self.args)
        return False


class Tracer:
    """Collects spans + instants and exports Chrome trace-event JSON.

    All timestamps are ``perf_counter`` seconds relative to the
    tracer's construction (``epoch``), exported as microseconds — the
    trace-event ``ts`` unit. One tracer = one trace file.
    """

    def __init__(self):
        self.epoch = time.perf_counter()
        self.events: list[dict] = []
        self._tids: dict[int, int] = {}

    def _tid(self) -> int:
        ident = threading.get_ident()
        tid = self._tids.get(ident)
        if tid is None:
            tid = self._tids[ident] = len(self._tids)
        return tid

    def span(self, name: str, **args) -> Span:
        """``with tracer.span("pack"): ...`` — records one complete event."""
        return Span(self, name, args or None)

    def complete(self, name: str, t0: float, t1: float, args: dict | None = None):
        """Record an already-measured span (the :class:`stopwatch` path)."""
        ev = {
            "name": name,
            "cat": "obs",
            "ph": "X",
            "ts": (t0 - self.epoch) * 1e6,
            "dur": max(t1 - t0, 0.0) * 1e6,
            "pid": 0,
            "tid": self._tid(),
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def instant(self, name: str, **args):
        """Record a zero-duration (instant) event — structured telemetry."""
        ev = {
            "name": name,
            "cat": "obs",
            "ph": "i",
            "s": "t",
            "ts": (time.perf_counter() - self.epoch) * 1e6,
            "pid": 0,
            "tid": self._tid(),
        }
        if args:
            ev["args"] = args
        self.events.append(ev)

    def chrome_trace(self, metadata: dict | None = None) -> dict:
        """The session as a Chrome trace-event JSON object (dict)."""
        trace = {
            "traceEvents": list(self.events),
            "displayTimeUnit": "ms",
        }
        if metadata:
            trace["otherData"] = dict(metadata)
        return trace

    def write_chrome_trace(self, path, metadata: dict | None = None) -> None:
        """Write the trace to ``path`` — open it at https://ui.perfetto.dev."""
        with open(path, "w") as f:
            json.dump(self.chrome_trace(metadata), f)
            f.write("\n")


class stopwatch:
    """Measure a block's wall seconds AND record a telemetry span.

    The one timing path for durations that must exist even when
    telemetry is disabled (the ``WaveSchedule.schedule_seconds`` /
    ``pack_seconds`` compatibility fields): ``perf_counter`` is always
    read, ``seconds`` is always set, and the span is recorded into the
    telemetry object's tracer only when it is enabled — one
    measurement, never two timing code paths.
    """

    __slots__ = ("_telemetry", "_name", "_args", "t0", "seconds")

    def __init__(self, telemetry, name: str, **args):
        self._telemetry = telemetry
        self._name = name
        self._args = args or None
        self.t0 = 0.0
        self.seconds = 0.0

    def __enter__(self) -> "stopwatch":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self.seconds = t1 - self.t0
        tel = self._telemetry
        if tel is not None and tel.enabled:
            tel.tracer.complete(self._name, self.t0, t1, self._args)
        return False
