from repro.checkpoint.manager import CheckpointManager, save_pytree, load_pytree
from repro.checkpoint.snapshots import (
    SnapshotCorruptError,
    SnapshotManager,
    SnapshotMismatchError,
)

__all__ = [
    "CheckpointManager",
    "save_pytree",
    "load_pytree",
    "SnapshotManager",
    "SnapshotMismatchError",
    "SnapshotCorruptError",
]
