"""Crash-safe :class:`repro.core.state.MatchState` snapshots.

A thin policy layer over :class:`repro.checkpoint.manager.CheckpointManager`
(which owns the write-tmp-fsync-rename commit protocol): the epoch
executor commits the carried state after every epoch, and resume loads
the latest committed step, validates it against the run it is being
resumed *into* (config fingerprint, format version, structural
integrity), and replays only the remaining stream suffix.

Validation failures are structured:

* :class:`SnapshotMismatchError` — the snapshot belongs to a different
  (stream, config, storage) triple; resuming would compute a wrong
  matching, so this is always an error, never a silent fresh start.
* :class:`SnapshotCorruptError` — the payload is internally
  inconsistent (torn arrays, cursor mismatch); with the fsync'd commit
  protocol this indicates storage corruption, not a crash artifact.

Telemetry: ``snapshot.save`` / ``snapshot.restore`` spans plus
same-named counters on the session's flat registry.
"""
from __future__ import annotations

import json
import os
from typing import Optional

from repro import obs
from repro.checkpoint.manager import CheckpointManager
from repro.core.state import STATE_VERSION, MatchState


class SnapshotMismatchError(RuntimeError):
    """Snapshot does not belong to the run being resumed."""


class SnapshotCorruptError(RuntimeError):
    """Snapshot payload is internally inconsistent."""


class SnapshotManager:
    """Commit/restore MatchState between epochs.

    ``directory`` is the snapshot root (one run per directory —
    snapshots are keyed by stream position, so mixing runs is exactly
    the mistake the fingerprint check exists to catch). ``keep`` and
    ``async_save`` pass through to the underlying
    :class:`CheckpointManager`; async saves overlap the file IO with
    the next epoch's device work, and :meth:`wait` (called by restore
    and by the epoch executor before returning) joins the writer.
    """

    def __init__(
        self,
        directory: str,
        keep: int = 2,
        async_save: bool = True,
        telemetry=obs.DISABLED,
    ):
        self.manager = CheckpointManager(
            directory, keep=keep, async_save=async_save
        )
        self.telemetry = telemetry

    @property
    def directory(self) -> str:
        return self.manager.directory

    # -------------------------------------------------------------- save

    def save(self, state: MatchState) -> None:
        """Commit ``state`` keyed by its stream position.

        The position is the step number, so ``all_steps()`` reads as
        the list of stream positions that are safely on disk and
        ``latest()`` resumes from the furthest one.
        """
        with self.telemetry.span("snapshot.save", pos=state.pos):
            self.manager.save(
                state.pos, {"match_state": state.to_arrays()},
                metadata=state.metadata(),
            )
            self.telemetry.count("snapshot.count")

    def wait(self) -> None:
        """Join a pending async write (no-op when sync or idle)."""
        self.manager.wait()

    def all_positions(self) -> list[int]:
        """Stream positions with a committed snapshot, ascending."""
        return self.manager.all_steps()

    # ----------------------------------------------------------- restore

    def _manifest(self, pos: int) -> dict:
        path = os.path.join(
            self.directory, f"step_{pos:08d}", "manifest.json"
        )
        with open(path) as f:
            return json.load(f)

    def latest(
        self, template: MatchState, pos: Optional[int] = None
    ) -> Optional[MatchState]:
        """Load the latest (or given-position) snapshot for this run.

        ``template`` is the pos-0 :meth:`MatchState.initial` of the run
        being resumed — it supplies the expected fingerprint and array
        shapes. Returns ``None`` when the directory holds no committed
        snapshot (fresh start), raises :class:`SnapshotMismatchError` /
        :class:`SnapshotCorruptError` on validation failure.
        """
        with self.telemetry.span("snapshot.restore"):
            self.wait()
            pos = pos if pos is not None else self.manager.latest_step()
            if pos is None:
                return None
            meta = self._manifest(pos)
            if meta.get("state_version") != STATE_VERSION:
                raise SnapshotMismatchError(
                    f"snapshot at pos {pos} has state_version "
                    f"{meta.get('state_version')!r}, expected {STATE_VERSION}"
                )
            if meta.get("fingerprint") != template.fingerprint:
                raise SnapshotMismatchError(
                    f"snapshot at pos {pos} fingerprints "
                    f"{meta.get('fingerprint')!r}, run fingerprints "
                    f"{template.fingerprint!r} — different stream, config, "
                    f"or storage layout"
                )
            _, trees = self.manager.restore(
                {"match_state": template.to_arrays()}, step=pos
            )
            state = MatchState.from_arrays(meta, trees["match_state"])
            problems = state.problems()
            if problems:
                raise SnapshotCorruptError(
                    f"snapshot at pos {pos} is inconsistent: "
                    + "; ".join(problems)
                )
            self.telemetry.count("snapshot.restore.count")
            return state
