"""Fault-tolerant checkpointing.

Design for the 1000-node regime:
  * checkpoints store *logical* (unsharded) arrays + a JSON manifest — a
    restore may use any mesh (elastic remesh restores with new shardings);
  * atomicity: write to ``step_XXXX.tmp/`` then ``os.rename`` — a crash
    mid-write never corrupts the latest checkpoint; the manifest is the
    commit record and is written last;
  * async save: device->host transfer happens on the caller thread (cheap,
    and consistent), file IO happens on a background thread so the train
    loop overlaps the write with the next steps;
  * retention: keep the newest ``keep`` checkpoints.

On a real multi-host cluster each host writes its owned shards and the
manifest lists them (shard-per-host layout); in this container the
single-process path writes full arrays. The format (npz + JSON manifest)
is deliberately dependency-free.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out


def save_pytree(tree, path: str) -> None:
    arrays = {
        k: np.asarray(v) for k, v in _flatten_with_paths(tree).items()
    }
    np.savez(path, **arrays)


def load_pytree(template, path: str, shardings=None):
    """Restore into the structure of `template` (ShapeDtypeStructs ok).

    `shardings`: optional matching pytree of NamedShardings — this is the
    elastic-remesh hook: the same file restores onto any mesh.
    """
    with np.load(path) as data:
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        flat_s = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat_t)
        )
        leaves = []
        for (path_t, leaf), shard in zip(flat_t, flat_s):
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path_t
            )
            arr = data[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save

    def save(self, step: int, state: dict[str, Any], metadata: Optional[dict] = None):
        """state: name -> pytree. Blocks only for device->host transfer."""
        host_state = {
            name: jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
            for name, tree in state.items()
        }
        meta = dict(metadata or {})
        meta.update({"step": step, "time": time.time(), "trees": sorted(host_state)})
        if self.async_save:
            self.wait()
            self._pending = threading.Thread(
                target=self._write, args=(step, host_state, meta), daemon=True
            )
            self._pending.start()
        else:
            self._write(step, host_state, meta)

    def _write(self, step: int, host_state, meta):
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, tree in host_state.items():
            save_pytree(tree, os.path.join(tmp, f"{name}.npz"))
        # manifest last: its presence inside the dir marks completeness
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, templates: dict[str, Any], step: Optional[int] = None,
                shardings: Optional[dict[str, Any]] = None):
        """Returns (step, {name: pytree}) or (None, None) if empty."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        base = os.path.join(self.directory, f"step_{step:08d}")
        out = {}
        for name, tmpl in templates.items():
            shard = (shardings or {}).get(name)
            out[name] = load_pytree(tmpl, os.path.join(base, f"{name}.npz"), shard)
        return step, out
