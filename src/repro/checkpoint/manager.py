"""Fault-tolerant checkpointing.

Design for the 1000-node regime:
  * checkpoints store *logical* (unsharded) arrays + a JSON manifest — a
    restore may use any mesh (elastic remesh restores with new shardings);
  * atomicity: write to ``step_XXXX.tmp/`` then ``os.rename`` — a crash
    mid-write never corrupts the latest checkpoint; the manifest is the
    commit record and is written last;
  * durability: every file and the containing directories are fsync'd
    around the rename (see :meth:`CheckpointManager._commit`) — a power
    loss after ``save`` returns can not roll back or tear the commit;
  * async save: device->host transfer happens on the caller thread (cheap,
    and consistent), file IO happens on a persistent writer thread fed by
    a bounded queue — the producer never joins an in-flight write, it
    only pays the host copy + enqueue, with backpressure once
    ``QUEUE_DEPTH`` snapshots are outstanding;
  * retention: keep the newest ``keep`` checkpoints.

On a real multi-host cluster each host writes its owned shards and the
manifest lists them (shard-per-host layout); in this container the
single-process path writes full arrays. The format (npz + JSON manifest)
is deliberately dependency-free.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out[key] = leaf
    return out


def save_pytree(tree, path: str) -> None:
    arrays = {
        k: np.asarray(v) for k, v in _flatten_with_paths(tree).items()
    }
    np.savez(path, **arrays)


def load_pytree(template, path: str, shardings=None):
    """Restore into the structure of `template` (ShapeDtypeStructs ok).

    `shardings`: optional matching pytree of NamedShardings — this is the
    elastic-remesh hook: the same file restores onto any mesh.
    """
    with np.load(path) as data:
        flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
        flat_s = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(flat_t)
        )
        leaves = []
        for (path_t, leaf), shard in zip(flat_t, flat_s):
            key = "/".join(
                str(getattr(p, "key", getattr(p, "idx", p))) for p in path_t
            )
            arr = data[key]
            if hasattr(leaf, "dtype"):
                arr = arr.astype(leaf.dtype)
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (directories need O_RDONLY)."""
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


class CheckpointManager:
    #: Bound on queued-but-unwritten async checkpoints. Each queued item
    #: holds a full host copy of the state, so the bound caps memory;
    #: a producer outrunning the writer blocks in ``save`` (backpressure)
    #: instead of accumulating snapshots without limit.
    QUEUE_DEPTH = 4

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._queue: Optional[queue.Queue] = None
        self._worker: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save

    def save(self, step: int, state: dict[str, Any], metadata: Optional[dict] = None):
        """state: name -> pytree. Blocks only for device->host transfer.

        Async saves hand the host copy to a persistent writer thread via
        a bounded queue — the caller never joins the in-flight write
        (the old spawn-and-join-previous pattern stalled the producer
        for the tail of the previous write whenever the writer ran
        slower than the step), so the producer-visible cost is just the
        device->host copy plus an enqueue.
        """
        host_state = {
            name: jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
            for name, tree in state.items()
        }
        meta = dict(metadata or {})
        meta.update({"step": step, "time": time.time(), "trees": sorted(host_state)})
        if self.async_save:
            self._ensure_worker()
            self._queue.put((step, host_state, meta))
        else:
            self._write(step, host_state, meta)

    def _ensure_worker(self):
        if self._worker is None or not self._worker.is_alive():
            # (re)start: the worker only dies when a write raised — the
            # exception escaped _drain after marking the item done, so a
            # later save must not enqueue onto a dead thread
            if self._queue is None:
                self._queue = queue.Queue(maxsize=self.QUEUE_DEPTH)
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    def _drain(self):
        while True:
            item = self._queue.get()
            try:
                self._write(*item)
            finally:
                # task_done in finally: wait() must unblock even when a
                # write dies (fault injection kills the commit mid-way)
                self._queue.task_done()

    def _write(self, step: int, host_state, meta):
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        for name, tree in host_state.items():
            save_pytree(tree, os.path.join(tmp, f"{name}.npz"))
        # manifest last: its presence inside the dir marks completeness
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        self._commit(tmp, final)
        self._gc()

    def _commit(self, tmp: str, final: str) -> None:
        """Crash-durable publish of a fully written ``tmp`` dir.

        ``os.rename`` alone is *atomic* but not *durable*: the data
        blocks, the tmp-dir entries, and the parent-dir rename can all
        still sit in the page cache when power is lost, leaving a
        renamed dir with torn npz payloads. Order of operations:
        fsync every file in ``tmp`` (payload hits disk), fsync ``tmp``
        itself (its directory entries hit disk), rename, then fsync the
        parent so the rename is journaled. Tests inject a crash here
        (faultline ``kill_mid_snapshot``) to prove a torn commit is
        never visible as the latest step."""
        for name in os.listdir(tmp):
            _fsync_path(os.path.join(tmp, name))
        _fsync_path(tmp)
        os.rename(tmp, final)
        _fsync_path(self.directory)

    def wait(self):
        """Block until every queued async write is durably committed
        (or died trying — fault-injected commits count as drained so a
        crashed writer can never deadlock the caller)."""
        if self._queue is not None:
            self._queue.join()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    # ---------------------------------------------------------- restore

    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and not d.endswith(".tmp"):
                if os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                    out.append(int(d[5:]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, templates: dict[str, Any], step: Optional[int] = None,
                shardings: Optional[dict[str, Any]] = None):
        """Returns (step, {name: pytree}) or (None, None) if empty."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        base = os.path.join(self.directory, f"step_{step:08d}")
        out = {}
        for name, tmpl in templates.items():
            shard = (shardings or {}).get(name)
            out[name] = load_pytree(tmpl, os.path.join(base, f"{name}.npz"), shard)
        return step, out
