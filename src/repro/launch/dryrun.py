import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) on the
production meshes and extract roofline terms from the compiled artifact.

MUST be run as its own process (the two lines above must execute before
any jax import anywhere): ``PYTHONPATH=src python -m repro.launch.dryrun``.

Outputs one JSON record per (arch, shape, mesh) to --out (resumable: cells
already present are skipped).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding  # noqa: E402

from repro.configs import all_arch_ids, get_arch  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import build_step  # noqa: E402
from repro.launch.roofline import (  # noqa: E402
    collective_bytes_from_hlo,
    roofline_terms,
    useful_flops,
)


def run_cell(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    arch = get_arch(arch_id)
    shape = arch.shapes[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    # LM cells: scanned program (fast compile; exact memory accounting),
    # flops/bytes/collectives from per-component compiles (components.py).
    # GNN/recsys: whole-program with unrolled chunk loops (exact costs).
    use_components = arch.family == "lm"
    # chunked-GNN cells: unrolling 2x16 chunks x 12 layers x grad exceeds
    # the single-core compile budget; run scans and apply the known
    # trip-count correction (the chunk loops dominate >99% of this model's
    # work, so multiplying the whole-program cost by the trip count is a
    # tight upper bound; flagged in the record).
    scan_corr = 1
    if arch.family == "gnn":
        from repro.launch.steps import gnn_batch_dims, gnn_shape_config

        gcfg = gnn_shape_config(arch, shape)
        if gcfg.edge_chunk:
            _, e_pad = gnn_batch_dims(shape, gcfg.edge_chunk)
            scan_corr = e_pad // gcfg.edge_chunk
    bs = build_step(arch, shape, multi_pod=multi_pod,
                    unroll=(not use_components) and scan_corr == 1)
    as_shard = lambda t: jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p), t,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )
    in_shardings = as_shard(bs.arg_pspecs)
    out_shardings = as_shard(bs.out_pspecs) if bs.out_pspecs is not None else None
    t0 = time.time()
    with mesh:
        lowered = jax.jit(
            bs.fn,
            in_shardings=in_shardings,
            out_shardings=out_shardings,
            donate_argnums=bs.donate,
        ).lower(*bs.arg_specs)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "kind": bs.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
    }
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        rec["memory"]["peak_per_device"] = (
            rec["memory"]["argument_bytes"]
            + rec["memory"]["output_bytes"]
            + rec["memory"]["temp_bytes"]
            - rec["memory"]["alias_bytes"]
        )
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": str(e)}
    if use_components:
        from repro.launch.components import lm_component_costs

        comp = lm_component_costs(arch, shape, mesh, multi_pod)
        rec["cost_method"] = "component"
        rec["flops_per_device"] = comp["total"]["flops"]
        rec["bytes_per_device"] = comp["total"]["bytes"]
        rec["collectives"] = {
            "total_bytes_per_device": comp["total"]["collective_bytes"]
        }
        rec["parts"] = comp["parts"]
    else:
        rec["cost_method"] = (
            "whole-program" if scan_corr == 1
            else f"whole-program-scan-corrected-x{scan_corr}"
        )
        ca = compiled.cost_analysis() or {}
        rec["flops_per_device"] = float(ca.get("flops", -1)) * scan_corr
        rec["bytes_per_device"] = float(ca.get("bytes accessed", -1)) * scan_corr
        hlo = compiled.as_text()
        rec["collectives"] = collective_bytes_from_hlo(hlo)
        if scan_corr > 1:
            rec["collectives"] = {
                k: (v * scan_corr if isinstance(v, float) else v)
                for k, v in rec["collectives"].items()
            }
    rec["model_flops"] = useful_flops(arch, shape)
    rec["roofline"] = roofline_terms(rec)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="single arch id (default: all)")
    ap.add_argument("--shape", default=None, help="single shape name")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    results: dict[str, dict] = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    arch_ids = [args.arch] if args.arch else all_arch_ids()
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_fail = 0
    for arch_id in arch_ids:
        arch = get_arch(arch_id)
        shape_names = [args.shape] if args.shape else list(arch.shapes)
        for shape_name in shape_names:
            for multi_pod in meshes:
                key = f"{arch_id}|{shape_name}|{'multi' if multi_pod else 'single'}"
                if key in results and not args.force and "error" not in results[key]:
                    print(f"skip {key} (cached)", flush=True)
                    continue
                print(f"=== {key}", flush=True)
                try:
                    rec = run_cell(arch_id, shape_name, multi_pod)
                    print(
                        f"    ok lower={rec['lower_s']}s compile={rec['compile_s']}s "
                        f"flops/dev={rec['flops_per_device']:.3e} "
                        f"peak/dev={rec.get('memory', {}).get('peak_per_device', -1)/2**30:.2f}GiB",
                        flush=True,
                    )
                except Exception as e:
                    n_fail += 1
                    rec = {
                        "arch": arch_id,
                        "shape": shape_name,
                        "mesh": "2x16x16" if multi_pod else "16x16",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-2000:],
                    }
                    print(f"    FAIL {rec['error'][:200]}", flush=True)
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    print(f"done; {n_fail} failures", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
