"""(arch x shape) -> jit-able step functions + shardings.

This is the seam between the model zoo and the production mesh: for every
architecture family it builds
  * ``state_specs``  — ArraySpec trees for params (+ AdamW state),
  * ``input_specs``  — ShapeDtypeStruct stand-ins for one step's inputs,
  * ``rules``        — logical-axis -> mesh-axis map (DP/TP/EP/SP/FSDP),
  * ``step_fn``      — train_step / prefill / decode / serve functions.

The dry-run lowers these against the production mesh; trainers jit them
against whatever mesh exists.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchSpec, ShapeSpec, sampled_subgraph_sizes
from repro.distributed.sharding import sharding_rules
from repro.models import bert4rec as b4r
from repro.models import transformer as tfm
from repro.models.param import ArraySpec, abstract_params, pspecs
from repro.optim import AdamWConfig, adamw_init_specs, adamw_update


def _gnn_module(arch: ArchSpec):
    import importlib

    return importlib.import_module(f"repro.models.{arch.gnn_model}")


def _rup(x: int, m: int) -> int:
    return (x + m - 1) // m * m


# --------------------------------------------------------------- rules


def arch_rules(arch: ArchSpec, shape: ShapeSpec, multi_pod: bool) -> dict:
    dp = ("pod", "data") if multi_pod else ("data",)
    model = "model"
    msize = 16
    rules: dict[str, Any] = {
        "dp": dp,
        "layers": None,
        "vocab": model,
        "mlp": model,
        "rows": model,
        "seq": None,
        "nodes": None,
        "edges": dp,
        "cache_batch": dp,
    }
    if arch.family == "lm":
        cfg: tfm.TransformerConfig = arch.config
        rules["embed"] = "data"  # FSDP: d_model rows over data
        # jit input shardings need divisibility: minicpm's 36 heads stay
        # replicated (documented inefficiency; see EXPERIMENTS §Roofline)
        rules["heads"] = model if cfg.n_heads % msize == 0 else None
        rules["kv_heads"] = model if cfg.n_kv % msize == 0 else None
        # heads-sharded archs: feature-dim boundary sharding beats
        # seq-sharding (grok layer: 35.3 -> ~21 GiB collectives, carry
        # stays 1/16-sized; EXPERIMENTS §Perf A-1). replicated-head archs
        # keep seq-sharding: it carries their seq-parallel attention.
        sharded_heads = cfg.n_heads % msize == 0
        rules["model_seq"] = None if sharded_heads else model
        rules["model_d"] = model if sharded_heads else None
        rules["expert"] = model if cfg.expert_sharding == "ep" else None
        rules["expert_mlp"] = model if cfg.expert_sharding == "tp" else None
        if shape.kind in ("decode", "prefill"):
            if shape.kind == "decode" and shape.global_batch == 1:
                rules["cache_batch"] = None
                rules["seq"] = dp + (model,) if rules["kv_heads"] is None else dp
            elif rules["kv_heads"] is None:
                rules["seq"] = model
    elif arch.family == "gnn":
        big = shape.n_nodes > 100_000
        # gin's node state (2.4M x 64 f32 = 627 MB) fits replicated: pure
        # edge-DP with an all-reduce per layer beats gathers (§Perf C)
        if shape.name == "ogb_products":
            rules["nodes"] = None if arch.id == "gin-tu" else ("data", model)
        else:
            rules["nodes"] = model if big else None
        rules["edges"] = dp + (model,) if big else dp
        rules["embed"] = None
    else:  # recsys
        rules["embed"] = None
        rules["heads"] = None
        rules["seq"] = None
        if shape.batch and shape.batch < 16:  # retrieval: a single query
            rules["dp"] = None
    return rules


# --------------------------------------------------------------- LM


def _lm_shape_overrides(cfg: tfm.TransformerConfig, shape: ShapeSpec,
                        unroll: bool = False, multi_pod: bool = False):
    # replicated-head archs (36 % 16 != 0) run sequence-parallel attention:
    # `attn_par` query chunks batched into one einsum, sharded over model
    sharded_heads = cfg.n_heads % 16 == 0
    par = 1 if sharded_heads else 16
    # MoE dispatch groups = DP degree (per-shard-local dispatch); decode
    # batches may be smaller than DP
    dp_size = 32 if multi_pod else 16
    groups = min(dp_size, shape.global_batch) if cfg.is_moe else 1
    if shape.kind == "train":
        return dataclasses.replace(
            cfg, attn_chunk=512 if sharded_heads else 256, attn_par=par,
            loss_chunk=256, unroll=unroll, moe_groups=groups,
        )
    if shape.kind == "prefill":
        return dataclasses.replace(
            cfg, attn_chunk=2048 if sharded_heads else 256, attn_par=par,
            loss_chunk=512, remat=True, unroll=unroll, moe_groups=groups,
        )
    return dataclasses.replace(cfg, unroll=unroll, moe_groups=groups)


def lm_state_specs(arch: ArchSpec, opt_cfg: AdamWConfig):
    pspec_tree = tfm.param_specs(arch.config)
    return pspec_tree, adamw_init_specs(pspec_tree, opt_cfg)


def lm_input_specs(arch: ArchSpec, shape: ShapeSpec):
    cfg: tfm.TransformerConfig = arch.config
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        return {"tokens": ArraySpec((B, S), ("dp", None), jnp.int32, "zeros")}
    if shape.kind == "prefill":
        return {"tokens": ArraySpec((B, S), ("dp", None), jnp.int32, "zeros")}
    if shape.kind == "decode":
        cache = tfm.kv_cache_specs(cfg, B, S)
        cache = jax.tree_util.tree_map(
            lambda s: ArraySpec(
                s.shape, ("layers", "cache_batch", "seq", "kv_heads", None),
                s.dtype, "zeros",
            ),
            cache,
            is_leaf=lambda x: isinstance(x, ArraySpec),
        )
        return {
            "cache": cache,
            "token": ArraySpec((B,), ("cache_batch",), jnp.int32, "zeros"),
        }
    raise ValueError(shape.kind)


def make_lm_train_step(arch: ArchSpec, shape: ShapeSpec, opt_cfg: AdamWConfig,
                       unroll: bool = False, multi_pod: bool = False):
    cfg = _lm_shape_overrides(arch.config, shape, unroll, multi_pod)

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lambda p: tfm.loss_fn(p, batch["tokens"], cfg))(
            params
        )
        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, opt_cfg.lr, opt_cfg
        )
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step


def make_lm_prefill(arch: ArchSpec, shape: ShapeSpec, unroll: bool = False,
                    multi_pod: bool = False):
    cfg = _lm_shape_overrides(arch.config, shape, unroll, multi_pod)

    def step(params, batch):
        cache, last_h = tfm.prefill(params, batch["tokens"], cfg)
        logits = (last_h @ params["lm_head"]).astype(jnp.float32)
        return cache, logits

    return step


def make_lm_decode(arch: ArchSpec, shape: ShapeSpec, unroll: bool = False,
                   multi_pod: bool = False):
    cfg = _lm_shape_overrides(arch.config, shape, unroll, multi_pod)
    S = shape.seq_len

    def step(params, batch):
        cache, token = batch["cache"], batch["token"]
        cache_len = jnp.int32(S - 1)
        logits, (knew, vnew) = tfm.decode_step(params, cache, token, cache_len, cfg)
        # commit the new KV at position cache_len (donated buffers in prod)
        k = jax.lax.dynamic_update_slice(
            cache["k"], knew.astype(cache["k"].dtype), (0, 0, S - 1, 0, 0)
        )
        v = jax.lax.dynamic_update_slice(
            cache["v"], vnew.astype(cache["v"].dtype), (0, 0, S - 1, 0, 0)
        )
        return logits, {"k": k, "v": v}

    return step


# --------------------------------------------------------------- GNN

N_SRC_BLOCKS = 16  # paper-style blocking: one node block resident/chunk


def gnn_edge_chunk(arch: ArchSpec, shape: ShapeSpec) -> int:
    # only the irrep-heavy model needs chunked message passing; everything
    # else fits [E_shard, d] comfortably (see DESIGN.md memory notes).
    # equiformer x products runs src-blocked (§Perf B): chunk = E / 16.
    if arch.id == "equiformer-v2" and shape.name == "ogb_products":
        e_pad = _rup(shape.n_edges, N_SRC_BLOCKS * 4096)
        return e_pad // N_SRC_BLOCKS
    return 0


def gnn_shape_config(arch: ArchSpec, shape: ShapeSpec, unroll: bool = False):
    cfg = arch.config
    over = dict(edge_chunk=gnn_edge_chunk(arch, shape), unroll=unroll)
    if arch.id == "equiformer-v2" and shape.name == "ogb_products":
        over["src_blocked"] = True
    if shape.name == "molecule":
        over["d_in"] = 16
    else:
        over["d_in"] = shape.d_feat
    if arch.id == "gin-tu" and shape.n_classes:
        over["n_classes"] = shape.n_classes
    return dataclasses.replace(cfg, **over)


def gnn_batch_dims(shape: ShapeSpec, chunk: int = 0):
    """(N_pad, E_pad) static sizes for the GraphBatch."""
    if shape.name == "minibatch_lg":
        n, e = sampled_subgraph_sizes(shape)
    elif shape.name == "molecule":
        n = shape.n_nodes * shape.batch_graphs
        e = shape.n_edges * shape.batch_graphs
    else:
        n, e = shape.n_nodes, shape.n_edges
    n = _rup(n, 256)
    e = _rup(e, chunk if chunk else 256)
    if chunk:
        e = _rup(e, chunk)
    return n, e


def gnn_input_specs(arch: ArchSpec, shape: ShapeSpec):
    cfg = gnn_shape_config(arch, shape)
    N, E = gnn_batch_dims(shape, cfg.edge_chunk)
    label_like = (
        ArraySpec((N,), ("nodes",), jnp.int32, "zeros")
        if arch.id == "gin-tu"
        else ArraySpec((N, cfg.d_out), ("nodes", None), jnp.float32, "zeros")
    )
    specs = {
        "node_feats": ArraySpec((N, cfg.d_in), ("nodes", None), jnp.float32),
        "src": ArraySpec((E,), ("edges",), jnp.int32, "zeros"),
        "dst": ArraySpec((E,), ("edges",), jnp.int32, "zeros"),
        "edge_mask": ArraySpec((E,), ("edges",), jnp.bool_, "zeros"),
        "node_mask": ArraySpec((N,), ("nodes",), jnp.bool_, "zeros"),
        "labels": label_like,
        "label_mask": ArraySpec((N,), ("nodes",), jnp.bool_, "zeros"),
    }
    if arch.id in ("egnn", "equiformer-v2", "meshgraphnet"):
        specs["coords"] = ArraySpec((N, 3), ("nodes", None), jnp.float32)
    return specs


def gnn_state_specs(arch: ArchSpec, shape: ShapeSpec, opt_cfg: AdamWConfig):
    mod = _gnn_module(arch)
    cfg = gnn_shape_config(arch, shape)
    pspec_tree = mod.param_specs(cfg)
    return pspec_tree, adamw_init_specs(pspec_tree, opt_cfg)


def make_gnn_train_step(arch: ArchSpec, shape: ShapeSpec, opt_cfg: AdamWConfig,
                        unroll: bool = False):
    mod = _gnn_module(arch)
    cfg = gnn_shape_config(arch, shape, unroll)
    from repro.models.gnn_common import GraphBatch

    def step(params, opt_state, batch):
        gb = GraphBatch(
            node_feats=batch["node_feats"],
            src=batch["src"],
            dst=batch["dst"],
            edge_mask=batch["edge_mask"],
            node_mask=batch["node_mask"],
            coords=batch.get("coords"),
            labels=batch["labels"],
            label_mask=batch["label_mask"],
        )
        loss, grads = jax.value_and_grad(lambda p: mod.loss_fn(p, gb, cfg))(params)
        params, opt_state, gnorm = adamw_update(
            params, grads, opt_state, opt_cfg.lr, opt_cfg
        )
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step


# --------------------------------------------------------------- recsys


def recsys_input_specs(arch: ArchSpec, shape: ShapeSpec):
    cfg: b4r.Bert4RecConfig = arch.config
    B = shape.batch
    base = {
        "item_ids": ArraySpec((B, cfg.seq_len), ("dp", None), jnp.int32, "zeros"),
        "context_ids": ArraySpec((B, cfg.n_context), ("dp", None), jnp.int32, "zeros"),
    }
    if shape.kind == "train":
        base |= {
            "mask_pos": ArraySpec((B, cfg.n_mask), ("dp", None), jnp.int32, "zeros"),
            "labels": ArraySpec((B, cfg.n_mask), ("dp", None), jnp.int32, "zeros"),
            "negatives": ArraySpec((cfg.n_negatives,), (None,), jnp.int32, "zeros"),
            "neg_logq": ArraySpec((cfg.n_negatives,), (None,), jnp.float32, "zeros"),
        }
    if shape.kind == "retrieval":
        base |= {
            "candidates": ArraySpec((shape.n_candidates,), ("rows",), jnp.int32, "zeros"),
        }
    return base


def recsys_state_specs(arch: ArchSpec, opt_cfg: AdamWConfig):
    pspec_tree = b4r.param_specs(arch.config)
    return pspec_tree, adamw_init_specs(pspec_tree, opt_cfg)


def sharded_topk(scores, k: int, shards: int = 16):
    """Two-stage top-k that never gathers the full score row."""
    B, V = scores.shape
    assert V % shards == 0
    s = scores.reshape(B, shards, V // shards)
    v1, i1 = jax.lax.top_k(s, k)  # [B, shards, k] (local per shard)
    base = (jnp.arange(shards) * (V // shards))[None, :, None]
    gidx = (i1 + base).reshape(B, shards * k)
    v2, i2 = jax.lax.top_k(v1.reshape(B, shards * k), k)
    return v2, jnp.take_along_axis(gidx, i2, axis=1)


def make_recsys_step(arch: ArchSpec, shape: ShapeSpec, opt_cfg: AdamWConfig,
                     unroll: bool = False):
    cfg: b4r.Bert4RecConfig = arch.config
    if shape.kind == "train":

        def step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(lambda p: b4r.loss_fn(p, batch, cfg))(params)
            params, opt_state, gnorm = adamw_update(
                params, grads, opt_state, opt_cfg.lr, opt_cfg
            )
            return params, opt_state, {"loss": loss, "grad_norm": gnorm}

        return step

    if shape.kind == "retrieval":

        def step(params, batch):
            scores = b4r.score_candidates(
                params, batch["item_ids"], batch["context_ids"], batch["candidates"], cfg
            )
            return sharded_topk(scores, k=100)

        return step

    # serve_scores: chunked scoring against the full table + 2-stage top-k
    B = shape.batch
    user_chunk = min(B, 4096)

    def step(params, batch):
        nb = B // user_chunk
        ids = batch["item_ids"].reshape(nb, user_chunk, cfg.seq_len)
        ctx = batch["context_ids"].reshape(nb, user_chunk, cfg.n_context)

        def one(_, xs):
            i, c = xs
            scores = b4r.serve_scores(params, i, c, cfg)
            return None, sharded_topk(scores, k=100)

        from repro.models.gnn_common import loop_chunks

        _, (vals, idxs) = loop_chunks(one, None, (ids, ctx), unroll)
        return vals.reshape(B, -1), idxs.reshape(B, -1)

    return step


# --------------------------------------------------------------- assembly


def _p(rules: dict, *logical) -> P:
    """Resolve logical axis names to a PartitionSpec under `rules`."""
    axes = []
    used = set()
    for name in logical:
        ax = rules.get(name) if name is not None else None
        key = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
        if ax is not None and any(k in used for k in key):
            ax = None
        if ax is not None:
            used.update(key)
        axes.append(ax)
    return P(*axes)


@dataclasses.dataclass
class BuiltStep:
    fn: Any  # (state..., batch) -> outputs
    arg_specs: tuple  # ShapeDtypeStruct pytrees, in call order
    arg_pspecs: tuple  # matching PartitionSpec pytrees
    out_pspecs: Any  # PartitionSpec pytree for outputs (or None -> infer)
    donate: tuple  # argnums to donate
    kind: str
    rules: dict


def default_opt_cfg(arch: ArchSpec) -> AdamWConfig:
    """>100B params: bf16 Adam moments (halves optimizer HBM; §Perf A-3)."""
    if arch.family == "lm" and arch.config.param_count() > 100e9:
        return AdamWConfig(moment_dtype=jnp.bfloat16)
    return AdamWConfig()


def build_step(arch: ArchSpec, shape: ShapeSpec, *, multi_pod: bool = False,
               opt_cfg: AdamWConfig | None = None, unroll: bool = False) -> BuiltStep:
    opt_cfg = opt_cfg or default_opt_cfg(arch)
    rules = arch_rules(arch, shape, multi_pod)

    def specs_of(tree):
        return abstract_params(tree), pspecs(tree, rules)

    out_pspecs = None
    donate: tuple = ()
    metrics_ps = {"loss": P(), "grad_norm": P()}
    if arch.family == "lm":
        inputs = lm_input_specs(arch, shape)
        if shape.kind == "train":
            p_t, o_t = lm_state_specs(arch, opt_cfg)
            fn = make_lm_train_step(arch, shape, opt_cfg, unroll, multi_pod)
            trees = (p_t, o_t, inputs)
            out_pspecs = (pspecs(p_t, rules), pspecs(o_t, rules), metrics_ps)
            donate = (0, 1)
        elif shape.kind == "prefill":
            p_t = tfm.param_specs(arch.config)
            fn = make_lm_prefill(arch, shape, unroll, multi_pod)
            trees = (p_t, inputs)
            cache_t = lm_input_specs(arch, dataclasses.replace(
                shape, kind="decode"))["cache"]
            out_pspecs = (pspecs(cache_t, rules), _p(rules, "dp", "vocab"))
        else:
            p_t = tfm.param_specs(arch.config)
            fn = make_lm_decode(arch, shape, unroll, multi_pod)
            trees = (p_t, inputs)
            cache_ps = pspecs(inputs["cache"], rules)
            out_pspecs = (_p(rules, "cache_batch", "vocab"), cache_ps)
            donate = (1,)
    elif arch.family == "gnn":
        p_t, o_t = gnn_state_specs(arch, shape, opt_cfg)
        inputs = gnn_input_specs(arch, shape)
        fn = make_gnn_train_step(arch, shape, opt_cfg, unroll)
        trees = (p_t, o_t, inputs)
        out_pspecs = (pspecs(p_t, rules), pspecs(o_t, rules), metrics_ps)
        donate = (0, 1)
    else:
        inputs = recsys_input_specs(arch, shape)
        if shape.kind == "train":
            p_t, o_t = recsys_state_specs(arch, opt_cfg)
            fn = make_recsys_step(arch, shape, opt_cfg, unroll)
            trees = (p_t, o_t, inputs)
            out_pspecs = (pspecs(p_t, rules), pspecs(o_t, rules), metrics_ps)
            donate = (0, 1)
        else:
            p_t = b4r.param_specs(arch.config)
            fn = make_recsys_step(arch, shape, opt_cfg, unroll)
            trees = (p_t, inputs)
            out_pspecs = (_p(rules, "dp", None), _p(rules, "dp", None))

    arg_specs, arg_pspecs = zip(*[specs_of(t) for t in trees])

    def wrapped(*args):
        with sharding_rules(rules):
            return fn(*args)

    return BuiltStep(
        fn=wrapped, arg_specs=tuple(arg_specs), arg_pspecs=tuple(arg_pspecs),
        out_pspecs=out_pspecs, donate=donate, kind=shape.kind, rules=rules,
    )
