"""Component-wise cost extraction for LM cells.

Compiling a 48-layer unrolled+remat train step under 512-way SPMD takes
>9 min on this host, while XLA's cost_analysis counts a scanned layer
once. So LM roofline terms are assembled from *component* compiles —
exact per-device HLO numbers, seconds each:

    train   = L x (layer fwd+bwd)  +  head+loss fwd+bwd  +  embed fwd+bwd
              + optimizer update
    prefill = L x (layer fwd)      +  final norm+logits
    decode  = L x (decode layer)   +  head

The *memory* number still comes from the full (scanned) program — while-
loop buffer accounting is exact there — so each cell reports
component-summed flops/bytes/collectives + whole-program peak memory.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ArchSpec, ShapeSpec
from repro.distributed.sharding import sharding_rules
from repro.launch.roofline import collective_bytes_from_hlo
from repro.launch.steps import _lm_shape_overrides, _p, arch_rules, lm_input_specs
from repro.models import transformer as tfm
from repro.models.param import ArraySpec, abstract_params, pspecs
from repro.optim import AdamWConfig, adamw_init_specs, adamw_update


def _layer_slice_specs(cfg):
    full = tfm.param_specs(cfg)["layers"]
    return jax.tree_util.tree_map(
        lambda s: ArraySpec(s.shape[1:], s.logical[1:], s.dtype, s.init),
        full,
        is_leaf=lambda x: isinstance(x, ArraySpec),
    )


def _costs(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    return {
        "flops": float(ca.get("flops", 0)),
        "bytes": float(ca.get("bytes accessed", 0)),
        "collective_bytes": collective_bytes_from_hlo(compiled.as_text())[
            "total_bytes_per_device"
        ],
    }


def _lower(fn, arg_trees, rules, mesh):
    specs = tuple(abstract_params(t) for t in arg_trees)
    shardings = tuple(
        jax.tree_util.tree_map(
            lambda p: NamedSharding(mesh, p), pspecs(t, rules),
            is_leaf=lambda x: isinstance(x, P),
        )
        for t in arg_trees
    )

    def wrapped(*args):
        with sharding_rules(rules):
            return fn(*args)

    with mesh:
        compiled = jax.jit(wrapped, in_shardings=shardings).lower(*specs).compile()
    return _costs(compiled)


def lm_component_costs(arch: ArchSpec, shape: ShapeSpec, mesh, multi_pod: bool,
                       opt_cfg: AdamWConfig | None = None) -> dict:
    """Returns per-device {flops, bytes, collective_bytes} + breakdown."""
    from repro.launch.steps import default_opt_cfg

    opt_cfg = opt_cfg or default_opt_cfg(arch)
    rules = arch_rules(arch, shape, multi_pod)
    cfg = _lm_shape_overrides(arch.config, shape, unroll=True, multi_pod=multi_pod)
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers
    dt = cfg.param_dtype
    parts: dict[str, dict] = {}

    x_spec = ArraySpec((B, S, d), ("dp", "model_seq", None), dt)
    lp_spec = _layer_slice_specs(cfg)
    positions = None  # built inside fns

    if shape.kind in ("train", "prefill"):
        if shape.kind == "train":

            def layer_fn(x, lp, ct):
                pos = jnp.arange(S)[None, :]
                body = jax.checkpoint(lambda xx, ll: tfm._layer(xx, ll, cfg, pos))
                y, vjp = jax.vjp(body, x, lp)
                dx, dl = vjp(ct)
                return y, dx, dl

            parts["layer"] = _lower(
                layer_fn, (x_spec, lp_spec, x_spec), rules, mesh
            )
            parts["layer"]["mult"] = L

            head_specs = {
                "h": x_spec,
                "lm_head": tfm.param_specs(cfg)["lm_head"],
                "labels": ArraySpec((B, S), ("dp", None), jnp.int32, "zeros"),
            }

            def head_fn(h, lm_head, labels):
                c = cfg.loss_chunk
                nchunk = S // c
                hc = jnp.moveaxis(h.reshape(B, nchunk, c, -1), 1, 0)
                lc = jnp.moveaxis(labels.reshape(B, nchunk, c), 1, 0)

                def loss(hh, w):
                    tot = jnp.float32(0)
                    for i in range(nchunk):
                        logits = (hh[i] @ w).astype(jnp.float32)
                        lse = jax.nn.logsumexp(logits, axis=-1)
                        gold = jnp.take_along_axis(logits, lc[i][..., None], -1)[..., 0]
                        tot += (lse - gold).sum()
                    return tot / (B * S)

                l, grads = jax.value_and_grad(loss, argnums=(0, 1))(hc, lm_head)
                return l, grads

            parts["head"] = _lower(
                head_fn,
                (head_specs["h"], head_specs["lm_head"], head_specs["labels"]),
                rules, mesh,
            )

            def embed_fn(tokens, table, ct):
                # fwd gather + bwd scatter-add, costed via a dot with the
                # cotangent so the vjp has the real structure
                f = lambda t: (
                    jnp.take(t, tokens, axis=0).astype(jnp.float32)
                    * ct.astype(jnp.float32)
                ).sum()
                return jax.grad(f)(table)

            parts["embed"] = _lower(
                embed_fn,
                (
                    ArraySpec((B, S), ("dp", None), jnp.int32, "zeros"),
                    tfm.param_specs(cfg)["embed"],
                    x_spec,
                ),
                rules, mesh,
            )

            p_t = tfm.param_specs(cfg)
            o_t = adamw_init_specs(p_t, opt_cfg)

            def opt_fn(params, grads, opt_state):
                return adamw_update(params, grads, opt_state, opt_cfg.lr, opt_cfg)

            parts["opt"] = _lower(opt_fn, (p_t, p_t, o_t), rules, mesh)
        else:  # prefill

            def layer_fn(x, lp):
                pos = jnp.arange(S)[None, :]
                return tfm._layer(x, lp, cfg, pos)

            parts["layer"] = _lower(layer_fn, (x_spec, lp_spec), rules, mesh)
            parts["layer"]["mult"] = L

            def head_fn(h, lm_head):
                return (h[:, -1] @ lm_head).astype(jnp.float32)

            parts["head"] = _lower(
                head_fn, (x_spec, tfm.param_specs(cfg)["lm_head"]), rules, mesh
            )
    else:  # decode
        cache_spec = lm_input_specs(arch, shape)["cache"]
        one_cache = jax.tree_util.tree_map(
            lambda s: ArraySpec(s.shape[1:], s.logical[1:], s.dtype, s.init),
            cache_spec,
            is_leaf=lambda x: isinstance(x, ArraySpec),
        )
        xd_spec = ArraySpec((B, 1, d), ("cache_batch", None, None), dt)

        def layer_fn(x, lp, kc, vc):
            cache_len = jnp.int32(S - 1)
            pos = jnp.full((B, 1), cache_len, jnp.int32)
            # inline decode layer (mirrors tfm.decode_step's one_layer,
            # incl. the virtual self slot)
            G = cfg.n_heads // cfg.n_kv
            h = tfm.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            q, kk, vv = tfm._qkv(h, lp, cfg, pos)
            qg = q.reshape(B, 1, cfg.n_kv, G, cfg.d_head)
            kc2 = jnp.concatenate([kc, kk.astype(kc.dtype)], axis=1)
            vc2 = jnp.concatenate([vc, vv.astype(vc.dtype)], axis=1)
            lmask = (jnp.arange(S + 1)[None, :] < cache_len).at[:, S].set(True)
            s = jnp.einsum("bqhgd,bshd->bhgqs", qg, kc2,
                           preferred_element_type=jnp.float32) / np.sqrt(cfg.d_head)
            s = jnp.where(lmask[:, None, None, None, :], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            attn = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(vc2.dtype), vc2,
                              preferred_element_type=jnp.float32)
            attn = attn.reshape(B, 1, cfg.n_heads, cfg.d_head)
            x = x + jnp.einsum("bshk,hkd->bsd", attn.astype(x.dtype), lp["wo"])
            h2 = tfm.rmsnorm(x, lp["ln2"], cfg.norm_eps)
            if cfg.is_moe:
                out = tfm._moe_ffn(h2.reshape(B, d), lp["router"], lp["w1"],
                                   lp["w2"], cfg)[:, None]
            else:
                out = tfm._activate(h2 @ lp["w1"], cfg.act) @ lp["w2"]
            return x + out.astype(x.dtype)

        parts["layer"] = _lower(
            layer_fn, (xd_spec, lp_spec, one_cache["k"], one_cache["v"]), rules, mesh
        )
        parts["layer"]["mult"] = L

        def head_fn(h, lm_head):
            return (h[:, 0] @ lm_head).astype(jnp.float32)

        parts["head"] = _lower(
            head_fn, (xd_spec, tfm.param_specs(cfg)["lm_head"]), rules, mesh
        )

    total = {"flops": 0.0, "bytes": 0.0, "collective_bytes": 0.0}
    for name, c in parts.items():
        mult = c.get("mult", 1)
        for k in total:
            total[k] += mult * c[k]
    return {"total": total, "parts": parts}
