"""Roofline-term extraction from compiled dry-run artifacts.

Hardware model (TPU v5e): 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI. Terms are per-step times in seconds:

  compute    = HLO_FLOPs / (chips * peak)     [cost_analysis is per-device,
                                               so: flops_per_device / peak]
  memory     = HLO_bytes / (chips * hbm_bw)   [ditto]
  collective = bytes moved per device over ICI / link_bw

Collective bytes come from parsing the (already SPMD-partitioned,
per-device) HLO: every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute contributes its ring-algorithm traffic:
  all-reduce     2 * out_bytes * (g-1)/g
  all-gather     out_bytes * (g-1)/g
  reduce-scatter in_bytes ~= out_bytes * (g-1)        (per-device send)
  all-to-all     out_bytes * (g-1)/g
  collective-permute out_bytes
where g is the replica-group size parsed from the op.
"""
from __future__ import annotations

import re

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes / s / chip
LINK_BW = 50e9  # bytes / s / link

# Substream-matching kernel model (the §5.11 optimality analogue): the
# pipeline retires edges at ``clock / cycles_per_edge`` when nothing
# stalls (~4 vector ops + loop overhead per edge, conservatively 8
# cycles), and the HBM side at ``HBM_BW / bytes_per_edge``. Consumed by
# ``repro.obs.report.MatchTelemetry.roofline`` and
# ``benchmarks/roofline_report.py``.
SUBSTREAM_CLOCK = 940e6  # TPU core clock used by the pipeline bound
SUBSTREAM_CYCLES_PER_EDGE = 8


def substream_bound(bytes_per_edge: float) -> dict:
    """Edges/sec roofline of the substream kernel at the given traffic.

    Two terms: the pipeline bound (1 edge per ``SUBSTREAM_CYCLES_PER_
    EDGE`` cycles at ``SUBSTREAM_CLOCK``) and the HBM bound (stream +
    amortized bit-row traffic, ``bytes_per_edge`` per edge). The
    binding term is the min; ``bytes_per_edge <= 0`` disables the
    memory term (pure pipeline bound).
    """
    pipeline = SUBSTREAM_CLOCK / SUBSTREAM_CYCLES_PER_EDGE
    memory = HBM_BW / bytes_per_edge if bytes_per_edge > 0 else float("inf")
    bound = min(pipeline, memory)
    return {
        "pipeline_edges_per_s": pipeline,
        "memory_edges_per_s": memory,
        "bound_edges_per_s": bound,
        "dominant": "pipeline" if pipeline <= memory else "memory",
        "bytes_per_edge": bytes_per_edge,
    }


def substream_achieved(edges_per_sec: float, bytes_per_edge: float) -> dict:
    """:func:`substream_bound` terms plus the achieved fraction."""
    terms = substream_bound(bytes_per_edge)
    terms["achieved_edges_per_s"] = edges_per_sec
    terms["achieved_fraction"] = (
        edges_per_sec / terms["bound_edges_per_s"]
        if terms["bound_edges_per_s"] > 0
        else 0.0
    )
    return terms

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_ARRAY_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|c64|c128)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[\w\[\],{}\/ ]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
    re.M,
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Returns {op_kind: bytes_moved_per_device} + totals."""
    out: dict[str, float] = {}
    count = 0
    lines = hlo.splitlines()
    for line in lines:
        m = _COLL_RE.match(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(type_str)
        if nbytes == 0:
            continue
        g = None
        gm = _GROUPS_RE.search(line)
        if gm:
            g = len(gm.group(1).split(","))
        else:
            gm = _GROUPS_IOTA_RE.search(line)
            if gm:
                g = int(gm.group(2))
        g = g or 2
        if kind == "all-reduce":
            moved = 2 * nbytes * (g - 1) / g
        elif kind == "all-gather":
            moved = nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            moved = nbytes * (g - 1)
        elif kind == "all-to-all":
            moved = nbytes * (g - 1) / g
        else:  # collective-permute
            moved = nbytes
        out[kind] = out.get(kind, 0.0) + moved
        count += 1
    out["total_bytes_per_device"] = sum(
        v for k, v in out.items() if k != "total_bytes_per_device"
    )
    out["n_ops"] = count
    return out


def roofline_terms(rec: dict) -> dict:
    fpd = max(rec.get("flops_per_device", 0), 0)
    bpd = max(rec.get("bytes_per_device", 0), 0)
    cpd = rec.get("collectives", {}).get("total_bytes_per_device", 0)
    compute_s = fpd / PEAK_FLOPS
    memory_s = bpd / HBM_BW
    coll_s = cpd / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s, "collective_s": coll_s}
    dom = max(terms, key=terms.get)
    bound = max(compute_s, memory_s, coll_s)
    mf = rec.get("model_flops", 0)
    n_chips = rec.get("n_chips", 1)
    terms["dominant"] = dom
    terms["step_time_lower_bound_s"] = bound
    if mf and fpd > 0:
        terms["useful_flop_ratio"] = mf / (fpd * n_chips)
        # fraction of roofline: useful work at peak vs. bound-implied time
        terms["roofline_fraction"] = (mf / (n_chips * PEAK_FLOPS)) / bound if bound else 0.0
    return terms


def useful_flops(arch, shape) -> float:
    """MODEL_FLOPS: 6*N*D (train) / 2*N*D (inference), N = active params.

    GNNs: parameter-matmul work per node/edge, x3 for bwd. Rough by design —
    it is the sanity ratio against compiled FLOPs, not a score.
    """
    fam = arch.family
    if fam == "lm":
        cfg = arch.config
        n_act = cfg.active_param_count()
        if shape.kind == "train":
            return 6.0 * n_act * shape.global_batch * shape.seq_len
        if shape.kind == "prefill":
            return 2.0 * n_act * shape.global_batch * shape.seq_len
        # decode: one token per sequence + attention over the cache
        attn = (
            2.0 * cfg.n_layers * cfg.n_kv * cfg.d_head * 2 * shape.seq_len
            * shape.global_batch
        )
        return 2.0 * n_act * shape.global_batch + attn
    if fam == "recsys":
        cfg = arch.config
        d = cfg.embed_dim
        enc = cfg.n_blocks * (4 * d * d + 8 * d * d)  # attn + ffn per token
        attn = cfg.n_blocks * 2 * cfg.seq_len * d  # score+mix per token
        per_seq = cfg.seq_len * (enc + attn)
        if shape.kind == "train":
            head = cfg.n_mask * (1 + cfg.n_negatives) * d * 2
            return 3.0 * shape.batch * (per_seq + head)
        if shape.kind == "retrieval":
            return shape.batch * per_seq + 2.0 * shape.n_candidates * d
        return shape.batch * (per_seq + 2.0 * cfg.item_vocab * d)
    # gnn
    from repro.launch.steps import gnn_batch_dims, gnn_shape_config

    cfg = gnn_shape_config(arch, shape)
    N, E = gnn_batch_dims(shape)
    d = cfg.d_hidden
    if arch.id == "gin-tu":
        per_node = 2 * (cfg.d_in * d + cfg.n_layers * 2 * d * d)
        per_edge = cfg.n_layers * d
        fwd = N * per_node + E * per_edge
    elif arch.id == "egnn":
        per_edge = cfg.n_layers * 2 * ((2 * d + 1) * d + d * d + d * d + d)
        per_node = cfg.n_layers * 2 * (2 * d * d + d * d)
        fwd = N * per_node + E * per_edge
    elif arch.id == "meshgraphnet":
        per_edge = cfg.n_layers * 2 * (3 * d * d + d * d + d * d)
        per_node = cfg.n_layers * 2 * (2 * d * d + d * d + d * d)
        fwd = N * per_node + E * per_edge
    else:  # equiformer-v2
        n_m = cfg.m_max + 1
        so2 = (cfg.l_max + 1) * d * d + sum(
            (cfg.l_max + 1 - m) * (2 * d) * (2 * d) for m in range(1, n_m)
        )
        per_edge = cfg.n_layers * 2 * 2 * so2  # x2 two-pass softmax
        per_node = cfg.n_layers * 2 * (cfg.n_heads * d * d + (cfg.l_max + 1) * d * d)
        fwd = N * per_node + E * per_edge
    return 3.0 * fwd
