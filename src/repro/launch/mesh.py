"""Production meshes.

Single pod: (data=16, model=16) — 256 chips, the v5e pod slice the roofline
table targets. Multi-pod: (pod=2, data=16, model=16) — 512 chips; the "pod"
axis is the slow (DCN) dimension, so only batch/DP traffic crosses it.

Functions, not module constants: importing this module must never touch
jax device state (smoke tests see 1 device; only dryrun forces 512).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — tests/examples."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def mesh_axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1
