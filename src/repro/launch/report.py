"""Render the dry-run JSON into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
import sys


def render_table(path: str, mesh: str = "16x16") -> str:
    data = json.load(open(path))
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL_FLOPS | useful/HLO | roofline frac | peak GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for k in sorted(data):
        v = data[k]
        if "error" in v:
            if v.get("mesh", mesh) == mesh:
                lines.append(f"| {v['arch']} | {v['shape']} | ERROR: {v['error'][:60]} |")
            continue
        if v["mesh"] != mesh:
            continue
        rf = v["roofline"]
        peak = v.get("memory", {}).get("peak_per_device", 0) / 2**30
        lines.append(
            f"| {v['arch']} | {v['shape']} | {rf['compute_s']:.3g} | "
            f"{rf['memory_s']:.3g} | {rf['collective_s']:.3g} | "
            f"{rf['dominant'].replace('_s','')} | {v['model_flops']:.3g} | "
            f"{rf.get('useful_flop_ratio', 0):.3f} | "
            f"{rf.get('roofline_fraction', 0)*100:.2f}% | {peak:.1f} |"
        )
    return "\n".join(lines)


def render_multipod_check(path: str) -> str:
    data = json.load(open(path))
    ok = sum(1 for v in data.values() if "error" not in v and v["mesh"] == "2x16x16")
    tot = sum(1 for v in data.values() if v.get("mesh") == "2x16x16")
    rows = []
    for k in sorted(data):
        v = data[k]
        if v.get("mesh") != "2x16x16" or "error" in v:
            continue
        peak = v.get("memory", {}).get("peak_per_device", 0) / 2**30
        rows.append(
            f"| {v['arch']} | {v['shape']} | {v['compile_s']}s | {peak:.1f} |"
        )
    header = (
        f"Multi-pod (2x16x16 = 512 chips): **{ok}/{tot} cells lower+compile OK**\n\n"
        "| arch | shape | compile | peak GiB/dev |\n|---|---|---|---|\n"
    )
    return header + "\n".join(rows)


if __name__ == "__main__":
    print(render_table(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"))
