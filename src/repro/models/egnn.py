"""EGNN — E(n)-equivariant GNN, arXiv:2102.09844 (exact formulation).

m_ij   = phi_e(h_i, h_j, ||x_i - x_j||^2)
x_i'   = x_i + (1/(deg+1)) * sum_j (x_i - x_j) * phi_x(m_ij)
h_i'   = phi_h(h_i, sum_j m_ij)

Invariance of h / equivariance of x under E(n) is exact and property-tested.
n_layers=4, d_hidden=64 (assigned config).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.gnn_common import GraphBatch, mlp_specs, mlp_apply, loop_chunks


@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 64
    d_out: int = 1  # per-node scalar target (e.g. energy density)
    edge_chunk: int = 0
    unroll: bool = False
    dtype: Any = jnp.float32


def param_specs(cfg: EGNNConfig):
    d = cfg.d_hidden
    return {
        "proj": mlp_specs((cfg.d_in, d), cfg.dtype),
        "layers": [
            {
                "phi_e": mlp_specs((2 * d + 1, d, d), cfg.dtype),
                "phi_x": mlp_specs((d, d, 1), cfg.dtype, final_zeros=True),
                "phi_h": mlp_specs((2 * d, d, d), cfg.dtype),
            }
            for _ in range(cfg.n_layers)
        ],
        "head": mlp_specs((d, cfg.d_out), cfg.dtype),
    }


def _layer(lp, h, x, batch: GraphBatch, cfg: EGNNConfig):
    src, dst, emask = batch.src, batch.dst, batch.edge_mask
    E = src.shape[0]
    chunk = cfg.edge_chunk or E
    assert E % chunk == 0
    nc = E // chunk

    def step(carry, xs):
        m_acc, xv_acc, cnt = carry
        s, d_, mk = xs
        rel = x[d_] - x[s]  # [c, 3] (x_i - x_j with i=dst)
        dist2 = (rel * rel).sum(-1, keepdims=True)
        m = mlp_apply(lp["phi_e"], jnp.concatenate([h[d_], h[s], dist2], -1))
        m = jnp.where(mk[:, None], m, 0)
        w = mlp_apply(lp["phi_x"], m)  # [c, 1]
        xv = jnp.where(mk[:, None], rel * jnp.tanh(w), 0)
        m_acc = m_acc + jax.ops.segment_sum(m, d_, num_segments=batch.n)
        xv_acc = xv_acc + jax.ops.segment_sum(xv, d_, num_segments=batch.n)
        cnt = cnt + jax.ops.segment_sum(mk.astype(cfg.dtype), d_, num_segments=batch.n)
        return (m_acc, xv_acc, cnt), None

    carry0 = (
        jnp.zeros((batch.n, cfg.d_hidden), cfg.dtype),
        jnp.zeros((batch.n, 3), cfg.dtype),
        jnp.zeros((batch.n,), cfg.dtype),
    )
    (m_i, xv_i, cnt), _ = loop_chunks(
        lambda c, xs: (step(c, xs)[0], None),
        carry0,
        (src.reshape(nc, chunk), dst.reshape(nc, chunk), emask.reshape(nc, chunk)),
        cfg.unroll,
    )
    x_new = x + xv_i / (cnt[:, None] + 1.0)
    h_new = mlp_apply(lp["phi_h"], jnp.concatenate([h, m_i], -1)) + h
    h_new = constrain(jnp.where(batch.node_mask[:, None], h_new, 0), "nodes", None)
    x_new = constrain(jnp.where(batch.node_mask[:, None], x_new, x), "nodes", None)
    return h_new, x_new


def forward(params, batch: GraphBatch, cfg: EGNNConfig):
    h = mlp_apply(params["proj"], batch.node_feats.astype(cfg.dtype))
    h = jnp.where(batch.node_mask[:, None], h, 0)
    x = batch.coords.astype(cfg.dtype)
    for lp in params["layers"]:
        h, x = _layer(lp, h, x, batch, cfg)
    return mlp_apply(params["head"], h), x


def loss_fn(params, batch: GraphBatch, cfg: EGNNConfig):
    out, _ = forward(params, batch, cfg)
    err = (out - batch.labels.astype(jnp.float32)) ** 2
    mask = batch.label_mask[:, None]
    return jnp.where(mask, err, 0).sum() / jnp.maximum(mask.sum() * cfg.d_out, 1)
