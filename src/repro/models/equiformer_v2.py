"""Equiformer-v2-style equivariant graph attention (arXiv:2306.12059).

eSCN trick (arXiv:2302.03655): rotate each edge's irrep features into the
edge-aligned frame, where the SO(3) tensor-product convolution becomes
block-diagonal in m — SO(2) 2x2 blocks — and truncate to |m| <= m_max.
This turns the O(l_max^6) CG contraction into O(l_max^3) work.

Fidelity note (see DESIGN.md §7): the azimuthal part of the edge alignment
(rotation about z by -phi) is implemented *exactly* — it is block-diagonal
cos/sin(m*phi) on real spherical harmonics. The polar (Wigner-d) part is
replaced by a learned per-(l, m) radial modulation; this preserves the
eSCN compute pattern (per-edge, per-m SO(2) block matmuls over channels,
attention in the invariant channel) but trades exact SO(3) equivariance of
the full layer for z-rotation equivariance. FLOP/memory structure — the
thing the roofline grades — matches the real model.

Features: X [N, (l_max+1)^2, C] real-SH irreps; attention: scalar (l=0)
channel -> per-head logits -> edge softmax -> weighted message sum.
Assigned: n_layers=12, d_hidden=128, l_max=6, m_max=2, heads=8.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.param import ArraySpec
from repro.distributed.sharding import constrain
from repro.models.gnn_common import GraphBatch, mlp_specs, mlp_apply, loop_chunks


@dataclasses.dataclass(frozen=True)
class EqV2Config:
    name: str = "equiformer-v2"
    n_layers: int = 12
    d_hidden: int = 128
    l_max: int = 6
    m_max: int = 2
    n_heads: int = 8
    d_in: int = 16
    d_out: int = 1
    n_radial: int = 16
    edge_chunk: int = 0
    unroll: bool = False
    # src-blocked message passing: the data pipeline sorts edges by source
    # block and each chunk i only reads node block i — the paper's
    # BRAM-epoch/blocking pattern (§4.2) applied to equivariant message
    # passing. Bounds the per-chunk gather working set to one replicated
    # X block instead of an all-gather of the full [N, n_coef, C] state.
    src_blocked: bool = False
    dtype: Any = jnp.float32

    @property
    def n_coef(self) -> int:
        return (self.l_max + 1) ** 2


def _lm_tables(l_max: int):
    """flat coefficient index -> (l, m); real-SH ordering m = -l..l."""
    ls, ms = [], []
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            ls.append(l)
            ms.append(m)
    return np.asarray(ls), np.asarray(ms)


def param_specs(cfg: EqV2Config):
    C, H = cfg.d_hidden, cfg.n_heads
    n_m = cfg.m_max + 1
    layers = []
    for _ in range(cfg.n_layers):
        layers.append(
            {
                # SO(2) conv weights: per retained m, [l-pairs folded into C]
                # realized as per-m channel-mixing matrices (eSCN style).
                "so2_w": ArraySpec((n_m, 2 * C, 2 * C), (None, None, None), cfg.dtype),
                "so2_w0": ArraySpec((C, C), (None, None), cfg.dtype),
                "radial": mlp_specs((cfg.n_radial, C, n_m * 2), cfg.dtype),
                "attn": mlp_specs((C, C, H), cfg.dtype),
                "val_mix": ArraySpec((H, C, C), (None, None, None), cfg.dtype),
                "gate": mlp_specs((C, C, (cfg.l_max + 1) * C), cfg.dtype),
                "ffn_w1": ArraySpec((C, 2 * C), (None, None), cfg.dtype),
                "ffn_w2": ArraySpec((2 * C, C), (None, None), cfg.dtype),
                "ln_scale": ArraySpec((C,), (None,), cfg.dtype, "ones"),
            }
        )
    return {
        "embed_scalar": mlp_specs((cfg.d_in, cfg.d_hidden), cfg.dtype),
        "layers": layers,
        "head": mlp_specs((cfg.d_hidden, cfg.d_hidden, cfg.d_out), cfg.dtype),
    }


def _equiv_layernorm(X, scale, eps=1e-5):
    """Norm over each l's vector length (equivariant); scale on channels."""
    # X: [N, n_coef, C]
    norm = jnp.sqrt((X * X).mean(axis=(1, 2), keepdims=True) + eps)
    return X / norm * scale[None, None, :]


def _radial_basis(dist, n_radial, r_max=6.0):
    mu = jnp.linspace(0.0, r_max, n_radial)
    beta = (n_radial / r_max) ** 2
    return jnp.exp(-beta * (dist[:, None] - mu[None, :]) ** 2)


def _zrot_tables(cfg: EqV2Config):
    """Static (numpy) tables — they index/branch at trace time."""
    ls, ms = _lm_tables(cfg.l_max)
    pos_of = {}
    for idx, (l, m) in enumerate(zip(ls, ms)):
        pos_of[(l, m)] = idx
    pair = np.asarray([pos_of[(l, -m)] for l, m in zip(ls, ms)])
    return ls, ms, pair


def _zrot(X, phi, ms, pair, inverse=False):
    """Exact real-SH rotation about z by angle phi (per edge).

    X: [E, n_coef, C]; phi: [E]. Components (l, m), (l, -m) mix with
    cos(m phi) / sin(m phi).
    """
    sgn = -1.0 if inverse else 1.0
    abs_m = jnp.asarray(np.abs(ms), X.dtype)
    ang = sgn * phi[:, None] * abs_m[None, :]  # [E, n_coef]
    c = jnp.cos(ang)[..., None]
    s = jnp.sin(ang)[..., None]
    Xp = X[:, np.asarray(pair), :]  # partner component (l, -m)
    msign = jnp.asarray(np.sign(ms), X.dtype)[None, :, None]
    # real-SH z-rotation: (l, m) and (l, -m) mix with cos/sin(m phi)
    return jnp.where(
        jnp.asarray(ms == 0)[None, :, None], X, c * X + msign * s * Xp
    )


def _layer(lp, X, batch: GraphBatch, cfg: EqV2Config, tables):
    ls, ms, pair = tables
    N, n_coef, C = X.shape
    E = batch.e
    chunk = cfg.edge_chunk or E
    assert E % chunk == 0
    nc = E // chunk
    n_m = cfg.m_max + 1
    mm = np.asarray(ms)
    m_keep = jnp.asarray(np.abs(mm) <= cfg.m_max)

    src_c = batch.src.reshape(nc, chunk)
    dst_c = batch.dst.reshape(nc, chunk)
    msk_c = batch.edge_mask.reshape(nc, chunk)
    idx_c = jnp.arange(nc)
    Nb = -(-N // nc)  # src-block size (src_blocked mode)

    def msg_chunk(i, s, d_, mk):
        rel = batch.coords[d_] - batch.coords[s]  # [c, 3]
        dist = jnp.linalg.norm(rel, axis=-1) + 1e-9
        phi = jnp.arctan2(rel[:, 1], rel[:, 0])
        rb = _radial_basis(dist, cfg.n_radial)  # [c, R]
        rmod = mlp_apply(lp["radial"], rb)  # [c, 2*n_m]
        if cfg.src_blocked:
            # chunk i's sources live in node block i (pipeline contract):
            # gather from one replicated block, never the full state
            Xblk = jax.lax.dynamic_slice_in_dim(X, i * Nb, Nb, 0)
            Xblk = constrain(Xblk, None, None, None)
            Xs = Xblk[jnp.clip(s - i * Nb, 0, Nb - 1)]
        else:
            Xs = X[s]  # [c, n_coef, C]
        Xs = constrain(Xs, "edges", None, None)
        Xr = _zrot(Xs, phi, ms, pair)  # align azimuth (exact)
        # eSCN SO(2) conv: m=0 block real matmul; m>0: stacked (m, -m) 2C vec
        out = jnp.zeros_like(Xr)
        is0 = (mm == 0)
        X0 = Xr[:, jnp.asarray(np.nonzero(is0)[0]), :]  # [c, l_max+1, C]
        y0 = jnp.einsum("clk,kj->clj", X0, lp["so2_w0"]) * rmod[:, None, 0:1]
        out = out.at[:, jnp.asarray(np.nonzero(is0)[0]), :].set(y0)
        for m in range(1, n_m):
            idx_p = np.nonzero((mm == m))[0]  # l >= m, ascending l
            idx_n = np.nonzero((mm == -m))[0]
            Xp_ = Xr[:, jnp.asarray(idx_p), :]  # [c, nl, C]
            Xn_ = Xr[:, jnp.asarray(idx_n), :]
            v = jnp.concatenate([Xp_, Xn_], axis=-1)  # [c, nl, 2C]
            y = jnp.einsum("cld,de->cle", v, lp["so2_w"][m]) * rmod[:, None, 2 * m : 2 * m + 1]
            yp, yn = jnp.split(y, 2, axis=-1)
            out = out.at[:, jnp.asarray(idx_p), :].set(yp)
            out = out.at[:, jnp.asarray(idx_n), :].set(yn)
        out = out * m_keep[None, :, None]  # m-truncation (eSCN)
        # attention logits from invariant channel
        inv = out[:, 0, :]  # [c, C]
        logits = mlp_apply(lp["attn"], inv)  # [c, H]
        out = _zrot(out, phi, ms, pair, inverse=True)
        return out, logits, mk

    # pass 1: edge max/sum for numerically-stable edge softmax (two-pass,
    # chunked; avoids [E, n_coef, C] materialization). Bodies are
    # checkpointed and emit *stacked partials* instead of threading a
    # carry: a differentiated scan saves its carry at every step, which
    # for a [N, n_coef, C] accumulator is the dominant memory term
    # (observed 945 GiB/device before this restructure; §Perf B-2).
    def pass1(_, xs):
        i, s, d_, mk = xs
        _, logits, _ = msg_chunk(i, s, d_, mk)
        logits = jnp.where(mk[:, None], logits, -jnp.inf)
        mx_p = jnp.full((N, cfg.n_heads), -jnp.inf, cfg.dtype).at[d_].max(logits)
        return None, mx_p

    _, mx_parts = loop_chunks(
        jax.checkpoint(pass1), None, (idx_c, src_c, dst_c, msk_c), cfg.unroll
    )
    mx = mx_parts.max(axis=0)
    mx = jnp.where(jnp.isfinite(mx), mx, 0.0)

    def pass2(_, xs):
        i, s, d_, mk = xs
        out, logits, _ = msg_chunk(i, s, d_, mk)
        w = jnp.exp(logits - mx[d_])  # [c, H]
        w = jnp.where(mk[:, None], w, 0.0)
        # value mixing per head, then weight and scatter
        vh = jnp.einsum("cnk,hkj->cnhj", out, lp["val_mix"])  # [c, n_coef, H, C]
        vw = (vh * w[:, None, :, None]).sum(axis=2)  # [c, n_coef, C]
        acc_p = jax.ops.segment_sum(vw, d_, num_segments=N)
        z_p = jax.ops.segment_sum(w, d_, num_segments=N)
        return None, (constrain(acc_p, "nodes", None, None), z_p)

    _, (acc_parts, z_parts) = loop_chunks(
        jax.checkpoint(pass2), None, (idx_c, src_c, dst_c, msk_c), cfg.unroll
    )
    acc = acc_parts.sum(axis=0)
    z = z_parts.sum(axis=0)
    agg = acc / jnp.maximum(z.sum(-1), 1e-9)[:, None, None]
    X = X + agg
    # gated nonlinearity: scalars gate each l block
    gates = jax.nn.sigmoid(mlp_apply(lp["gate"], X[:, 0, :]))  # [N, (l_max+1)*C]
    gates = gates.reshape(N, cfg.l_max + 1, C)[:, np.asarray(ls), :]
    ff = mlp_apply({"w0": lp["ffn_w1"], "b0": jnp.zeros((2 * C,), cfg.dtype)}, X[:, 0, :])
    ff = jax.nn.silu(ff) @ lp["ffn_w2"]
    X = X * gates
    X = X.at[:, 0, :].add(ff)
    X = _equiv_layernorm(X, lp["ln_scale"])
    X = constrain(jnp.where(batch.node_mask[:, None, None], X, 0), "nodes", None, None)
    return X


def forward(params, batch: GraphBatch, cfg: EqV2Config):
    tables = _zrot_tables(cfg)
    N = batch.n
    h0 = mlp_apply(params["embed_scalar"], batch.node_feats.astype(cfg.dtype))
    X = jnp.zeros((N, cfg.n_coef, cfg.d_hidden), cfg.dtype).at[:, 0, :].set(h0)
    X = jnp.where(batch.node_mask[:, None, None], X, 0)
    # NOTE: per-layer remat was tried and REFUTED here — recomputing the
    # forward re-gathers every blocked X slice, inflating collectives 2.5x
    # and *raising* peak memory (187 -> 304 GiB); see EXPERIMENTS §Perf B-3.
    for lp in params["layers"]:
        X = _layer(lp, X, batch, cfg, tables)
    return mlp_apply(params["head"], X[:, 0, :])


def loss_fn(params, batch: GraphBatch, cfg: EqV2Config):
    out = forward(params, batch, cfg).astype(jnp.float32)
    err = (out - batch.labels.astype(jnp.float32)) ** 2
    mask = batch.label_mask[:, None]
    return jnp.where(mask, err, 0).sum() / jnp.maximum(mask.sum() * cfg.d_out, 1)
