"""Shared GNN machinery: flat GraphBatch + MLP + chunked message passing.

All four GNN shapes reduce to one flat representation:
  * full-batch graphs: one graph, masks all-true;
  * sampled minibatch (fanout 15-10): the sampler's merged subgraph;
  * batched small molecules: disjoint union, ``graph_ids`` for readout.

JAX has no CSR SpMM — message passing is gather -> transform ->
``segment_sum`` (see repro.graph.segment), with optional edge chunking
(lax.scan) so multi-10M-edge graphs never materialize [E, d] at once.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.param import ArraySpec


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    node_feats: jax.Array  # [N, F] float
    src: jax.Array  # [E] int32
    dst: jax.Array  # [E] int32
    edge_mask: jax.Array  # [E] bool
    node_mask: jax.Array  # [N] bool
    coords: Optional[jax.Array] = None  # [N, 3]
    edge_feats: Optional[jax.Array] = None  # [E, Fe]
    graph_ids: Optional[jax.Array] = None  # [N] int32 (batched readout)
    labels: Optional[jax.Array] = None  # [N] int32 or [N/B, ...] float
    label_mask: Optional[jax.Array] = None  # [N] or [B] bool

    @property
    def n(self) -> int:
        return self.node_feats.shape[0]

    @property
    def e(self) -> int:
        return self.src.shape[0]


def mlp_specs(name_dims, dtype=jnp.float32, final_zeros: bool = False):
    """[(d0, d1, d2, ...)] -> {wi, bi} specs. Logical axes: generic."""
    specs = {}
    dims = name_dims
    for i in range(len(dims) - 1):
        init = "zeros" if (final_zeros and i == len(dims) - 2) else "normal"
        specs[f"w{i}"] = ArraySpec((dims[i], dims[i + 1]), (None, None), dtype, init)
        specs[f"b{i}"] = ArraySpec((dims[i + 1],), (None,), dtype, "zeros")
    return specs


def mlp_apply(params, x, act=jax.nn.silu, layernorm: bool = False, eps=1e-5):
    n = len([k for k in params if k.startswith("w")])
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n - 1:
            x = act(x)
    if layernorm:
        mu = x.mean(-1, keepdims=True)
        var = ((x - mu) ** 2).mean(-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + eps)
    return x


def loop_chunks(body, carry, xs, unroll: bool):
    """scan-or-python-loop over the leading axis of `xs` (a tuple tree).

    Unrolled mode exists for the dry-run: XLA cost_analysis counts a
    while-loop body once, so chunked message passing must be unrolled for
    honest FLOP/byte roofline numbers.
    """
    if not unroll:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    outs = []
    for i in range(n):
        carry, o = body(carry, jax.tree_util.tree_map(lambda a: a[i], xs))
        outs.append(o)
    if outs and outs[0] is not None:
        outs = jax.tree_util.tree_map(lambda *ys: jnp.stack(ys), *outs)
    else:
        outs = None
    return carry, outs


def chunked_edge_aggregate(msg_fn, src, dst, edge_mask, n_nodes: int,
                           out_dim: int, edge_chunk: int = 0, dtype=jnp.float32,
                           unroll: bool = False):
    """sum_{e: dst(e)=v} msg_fn(e_indices) with optional chunking.

    msg_fn(src_idx, dst_idx, mask) -> [chunk, out_dim] messages.
    """
    E = src.shape[0]
    if not edge_chunk or E <= edge_chunk:
        m = msg_fn(src, dst, edge_mask)
        m = jnp.where(edge_mask[:, None], m, 0)
        return jax.ops.segment_sum(m, dst, num_segments=n_nodes)
    assert E % edge_chunk == 0, (E, edge_chunk)
    nc = E // edge_chunk
    s = src.reshape(nc, edge_chunk)
    d = dst.reshape(nc, edge_chunk)
    em = edge_mask.reshape(nc, edge_chunk)

    def step(acc, xs):
        si, di, mi = xs
        m = msg_fn(si, di, mi)
        m = jnp.where(mi[:, None], m, 0)
        return acc + jax.ops.segment_sum(m, di, num_segments=n_nodes), None

    acc0 = jnp.zeros((n_nodes, out_dim), dtype)
    acc, _ = loop_chunks(step, acc0, (s, d, em), unroll)
    return acc
