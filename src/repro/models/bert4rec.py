"""BERT4Rec — arXiv:1904.06690. Bidirectional transformer over item
sequences with masked-item (Cloze) training.

Assigned: embed_dim=64, n_blocks=2, n_heads=2, seq_len=200, bidirectional.

Huge-item-vocab handling:
  * item table row-sharded over the model axis;
  * training uses sampled softmax (shared negatives + logQ correction) —
    full [B, S, V] logits never exist;
  * serving scores sequences against the full table (retrieval matmul);
  * a context EmbeddingBag (jnp.take + segment_sum, models/embedding.py)
    pools multi-hot user-context ids into the sequence representation.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.param import ArraySpec
from repro.models.embedding import embedding_bag


@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    name: str = "bert4rec"
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    item_vocab: int = 1_000_000
    n_context: int = 16  # context bag size (EmbeddingBag path)
    n_mask: int = 40  # masked positions per sequence (20 %)
    n_negatives: int = 8192  # sampled-softmax shared negatives
    norm_eps: float = 1e-6
    dtype: Any = jnp.float32


def param_specs(cfg: Bert4RecConfig):
    d = cfg.embed_dim
    layers = []
    for _ in range(cfg.n_blocks):
        layers.append(
            {
                "wqkv": ArraySpec((d, 3 * d), ("embed", "heads"), cfg.dtype),
                "wo": ArraySpec((d, d), ("heads", "embed"), cfg.dtype),
                "ln1": ArraySpec((d,), (None,), cfg.dtype, "ones"),
                "ln2": ArraySpec((d,), (None,), cfg.dtype, "ones"),
                "w1": ArraySpec((d, 4 * d), ("embed", "mlp"), cfg.dtype),
                "b1": ArraySpec((4 * d,), ("mlp",), cfg.dtype, "zeros"),
                "w2": ArraySpec((4 * d, d), ("mlp", "embed"), cfg.dtype),
                "b2": ArraySpec((d,), (None,), cfg.dtype, "zeros"),
            }
        )
    return {
        "items": ArraySpec((cfg.item_vocab, d), ("rows", "embed"), cfg.dtype, "embed", 0.02),
        "pos": ArraySpec((cfg.seq_len, d), ("seq", "embed"), cfg.dtype, "embed", 0.02),
        "context": ArraySpec((cfg.item_vocab, d), ("rows", "embed"), cfg.dtype, "embed", 0.02),
        "layers": layers,
        "ln_f": ArraySpec((d,), (None,), cfg.dtype, "ones"),
    }


def _ln(x, scale, eps):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * scale


def encode(params, item_ids, context_ids, cfg: Bert4RecConfig):
    """item_ids [B, S]; context_ids [B, n_context] -> hidden [B, S, d]."""
    B, S = item_ids.shape
    d, H = cfg.embed_dim, cfg.n_heads
    x = jnp.take(params["items"], item_ids, axis=0) + params["pos"][None, :S]
    ctx = embedding_bag(params["context"], context_ids, mode="mean",
                        valid=context_ids >= 0)
    x = x + ctx[:, None, :]
    for lp in params["layers"]:
        h = _ln(x, lp["ln1"], cfg.norm_eps)
        qkv = (h @ lp["wqkv"]).reshape(B, S, 3, H, d // H)
        q, k, v = qkv[:, :, 0], qkv[:, :, 1], qkv[:, :, 2]
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k, preferred_element_type=jnp.float32)
        s = s / np.sqrt(d // H)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        attn = jnp.einsum("bhqk,bkhd->bqhd", p, v).reshape(B, S, d)
        x = x + attn @ lp["wo"]
        h2 = _ln(x, lp["ln2"], cfg.norm_eps)
        x = x + jax.nn.gelu(h2 @ lp["w1"] + lp["b1"]) @ lp["w2"] + lp["b2"]
    return _ln(x, params["ln_f"], cfg.norm_eps)


def loss_fn(params, batch, cfg: Bert4RecConfig):
    """Cloze loss with sampled softmax.

    batch: item_ids [B,S], context_ids [B,nc], mask_pos [B,n_mask] int32,
    labels [B,n_mask] int32, negatives [n_neg] int32 (shared),
    neg_logq [n_neg] f32 (log sampling prob for correction).
    """
    h = encode(params, batch["item_ids"], batch["context_ids"], cfg)
    hm = jnp.take_along_axis(
        h, batch["mask_pos"][..., None], axis=1
    )  # [B, n_mask, d]
    pos_emb = jnp.take(params["items"], batch["labels"], axis=0)  # [B,n_mask,d]
    neg_emb = jnp.take(params["items"], batch["negatives"], axis=0)  # [n_neg,d]
    pos_logit = (hm * pos_emb).sum(-1, keepdims=True).astype(jnp.float32)
    neg_logit = jnp.einsum("bmd,nd->bmn", hm, neg_emb).astype(jnp.float32)
    neg_logit = neg_logit - batch["neg_logq"][None, None, :]  # logQ correction
    logits = jnp.concatenate([pos_logit, neg_logit], axis=-1)
    nll = jax.nn.logsumexp(logits, axis=-1) - logits[..., 0]
    return nll.mean()


def score_candidates(params, item_ids, context_ids, candidates, cfg: Bert4RecConfig):
    """Retrieval scoring: last-position user repr vs candidate item rows.

    candidates int32 [n_cand] -> scores [B, n_cand].
    """
    h = encode(params, item_ids, context_ids, cfg)[:, -1]  # [B, d]
    cand = jnp.take(params["items"], candidates, axis=0)  # [n_cand, d]
    return jnp.einsum("bd,nd->bn", h, cand, preferred_element_type=jnp.float32)


def serve_scores(params, item_ids, context_ids, cfg: Bert4RecConfig):
    """Online/bulk serving: score against the *full* item table."""
    h = encode(params, item_ids, context_ids, cfg)[:, -1]
    return jnp.einsum(
        "bd,vd->bv", h, params["items"], preferred_element_type=jnp.float32
    )
