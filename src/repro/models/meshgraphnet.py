"""MeshGraphNet — arXiv:2010.03409. Encode-Process-Decode.

Encoder: node/edge MLPs into latent d=128.
Processor (15 steps): e' = e + MLP([e, h_src, h_dst]); h' = h + MLP([h, sum e']).
Decoder: node MLP -> output (acceleration).
All MLPs: 2 hidden layers + LayerNorm (paper setup). Assigned: n_layers=15,
d_hidden=128, sum aggregator, mlp_layers=2.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.gnn_common import GraphBatch, mlp_specs, mlp_apply, loop_chunks


@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    d_in: int = 16
    d_edge_in: int = 4  # rel coords (3) + norm (1)
    d_out: int = 3
    edge_chunk: int = 0
    unroll: bool = False
    dtype: Any = jnp.float32


def param_specs(cfg: MGNConfig):
    d = cfg.d_hidden
    return {
        "enc_node": mlp_specs((cfg.d_in, d, d, d), cfg.dtype),
        "enc_edge": mlp_specs((cfg.d_edge_in, d, d, d), cfg.dtype),
        "layers": [
            {
                "edge_mlp": mlp_specs((3 * d, d, d, d), cfg.dtype),
                "node_mlp": mlp_specs((2 * d, d, d, d), cfg.dtype),
            }
            for _ in range(cfg.n_layers)
        ],
        "dec": mlp_specs((d, d, d, cfg.d_out), cfg.dtype),
    }


def _edge_feats(batch: GraphBatch, cfg: MGNConfig):
    if batch.edge_feats is not None:
        return batch.edge_feats.astype(cfg.dtype)
    rel = batch.coords[batch.dst] - batch.coords[batch.src]
    norm = jnp.linalg.norm(rel, axis=-1, keepdims=True)
    return jnp.concatenate([rel, norm], -1).astype(cfg.dtype)


def forward(params, batch: GraphBatch, cfg: MGNConfig):
    h = mlp_apply(params["enc_node"], batch.node_feats.astype(cfg.dtype), layernorm=True)
    e = mlp_apply(params["enc_edge"], _edge_feats(batch, cfg), layernorm=True)
    h = jnp.where(batch.node_mask[:, None], h, 0)
    e = jnp.where(batch.edge_mask[:, None], e, 0)
    E = batch.e
    chunk = cfg.edge_chunk or E
    assert E % chunk == 0
    nc = E // chunk
    src_c = batch.src.reshape(nc, chunk)
    dst_c = batch.dst.reshape(nc, chunk)
    msk_c = batch.edge_mask.reshape(nc, chunk)

    for lp in params["layers"]:
        e_chunks = e.reshape(nc, chunk, cfg.d_hidden)

        def step(agg, xs):
            s, d_, mk, ec = xs
            inp = jnp.concatenate([ec, h[s], h[d_]], -1)
            e_new = ec + mlp_apply(lp["edge_mlp"], inp, layernorm=True)
            e_new = jnp.where(mk[:, None], e_new, 0)
            agg = agg + jax.ops.segment_sum(e_new, d_, num_segments=batch.n)
            return agg, e_new

        agg0 = jnp.zeros((batch.n, cfg.d_hidden), cfg.dtype)
        agg, e_new = loop_chunks(step, agg0, (src_c, dst_c, msk_c, e_chunks), cfg.unroll)
        e = e_new.reshape(E, cfg.d_hidden)
        h = h + mlp_apply(lp["node_mlp"], jnp.concatenate([h, agg], -1), layernorm=True)
        h = constrain(jnp.where(batch.node_mask[:, None], h, 0), "nodes", None)
    return mlp_apply(params["dec"], h)


def loss_fn(params, batch: GraphBatch, cfg: MGNConfig):
    out = forward(params, batch, cfg).astype(jnp.float32)
    err = (out - batch.labels.astype(jnp.float32)) ** 2
    mask = batch.label_mask[:, None]
    return jnp.where(mask, err, 0).sum() / jnp.maximum(mask.sum() * cfg.d_out, 1)
