"""Parameter declaration system.

Models declare parameters as trees of :class:`ArraySpec` (shape + logical
axis names + init). The same tree serves three consumers:

  * ``init_params``     — materialize real arrays (examples, smoke tests);
  * ``abstract_params`` — ShapeDtypeStructs (dry-run lowering: grok-314B is
    never materialized on the CPU host);
  * ``pspecs``          — PartitionSpecs from a logical→mesh-axis rule map.

Logical axis vocabulary (rules map these to mesh axes or None):
  "dp"      batch/tokens            "embed"   d_model rows
  "heads"   attention heads         "kv_heads" kv heads
  "mlp"     FFN hidden              "vocab"   vocabulary rows
  "expert"  MoE expert dim          "expert_mlp" per-expert FFN hidden
  "layers"  stacked scan dim        "seq"     sequence
  "nodes"/"edges" graph dims        "rows"    embedding-table rows
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ArraySpec:
    shape: tuple
    logical: tuple  # one name (or None) per dim
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | embed
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _fan_in(shape) -> float:
    return float(shape[-2]) if len(shape) >= 2 else float(shape[-1])


def init_params(spec_tree, rng_key):
    leaves, treedef = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ArraySpec)
    )
    keys = jax.random.split(rng_key, len(leaves))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, spec.dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, spec.dtype)
        else:
            scale = spec.scale
            if scale is None:
                scale = 1.0 if spec.init == "embed" else 1.0 / np.sqrt(_fan_in(spec.shape))
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * scale).astype(
                spec.dtype
            )
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def abstract_params(spec_tree):
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        spec_tree,
        is_leaf=lambda x: isinstance(x, ArraySpec),
    )


def pspecs(spec_tree, rules: dict):
    """rules: logical axis name -> mesh axis (str | tuple | None)."""

    def one(spec: ArraySpec) -> P:
        axes = []
        used = set()
        for name in spec.logical:
            ax = rules.get(name) if name is not None else None
            # a mesh axis may appear only once in a PartitionSpec
            key = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
            if ax is not None and any(k in used for k in key):
                ax = None
            if ax is not None:
                used.update(key)
            axes.append(ax)
        return P(*axes)

    return jax.tree_util.tree_map(
        one, spec_tree, is_leaf=lambda x: isinstance(x, ArraySpec)
    )


def shardings(spec_tree, rules: dict, mesh):
    from jax.sharding import NamedSharding

    return jax.tree_util.tree_map(
        lambda p: NamedSharding(mesh, p),
        pspecs(spec_tree, rules),
        is_leaf=lambda x: isinstance(x, P),
    )


def count_params(spec_tree) -> int:
    leaves = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, ArraySpec)
    )
    return int(sum(int(np.prod(s.shape)) for s in leaves))
