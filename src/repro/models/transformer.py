"""Decoder-only transformer LM family (dense GQA + MoE variants).

Covers internlm2-20b, minicpm-2b, gemma-7b (dense) and
moonshot-v1-16b-a3b, grok-1-314b (MoE). Pure-function style: params are
pytrees declared via ArraySpec (models/param.py); every public entry point
is jit/pjit-compatible with static config.

Memory discipline for the production mesh:
  * per-layer `jax.checkpoint` (remat); activations between layers are
    constrained to ("dp", "model", None) — Megatron-style sequence
    parallelism, so layer-boundary residuals stay ~MB-scale per device;
  * attention loops over query chunks, each chunk checkpointed: scores for
    one [B, c, H_loc, S] block are the only attention transient;
  * the LM head + loss run in sequence chunks — no [B, S, V] tensor;
  * MoE: capacity-factor dispatch into an [E, C, d] buffer (EP or TP).

``unroll=True`` replaces every lax.scan with a Python loop. The dry-run
uses it because XLA's cost_analysis counts a while-loop body once (not
x trip count); training keeps scans for compile speed. Both paths produce
identical math (tested).

Attention params are kept head-major ([d, H, Dh] etc.) so the head axis
shards directly — including non-divisible head counts (GSPMD pads), e.g.
minicpm's 36 heads on a 16-wide model axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.param import ArraySpec


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_head: int
    d_ff: int
    vocab: int
    act: str = "swiglu"  # swiglu | geglu | gelu
    # MoE (n_experts == 0 -> dense FFN)
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    expert_sharding: str = "ep"  # "ep" | "tp"
    moe_groups: int = 1  # dispatch groups (= DP shards in production)
    # EPxTP folding: when n_experts < TP width, each expert's FFN dim is
    # split into `expert_fold` slices stored as separate "half-experts",
    # so the (folded) expert dim shards the full model axis and expert
    # traffic is activations (all-to-all), never weights. grok: 8e x2.
    expert_fold: int = 1
    # misc
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    vocab_pad_to: int = 256
    param_dtype: Any = jnp.bfloat16
    attn_chunk: int = 512
    attn_par: int = 1  # chunks batched per attention einsum (see attention())
    loss_chunk: int = 512
    logit_softcap: float = 0.0
    embed_scale: bool = False  # gemma: embeddings * sqrt(d_model)
    remat: bool = True
    unroll: bool = False  # python loops instead of lax.scan (dry-run)
    # GQA kv heads that do not divide the TP axis are replicated; expanding
    # kv to full heads *before* attention keeps the score einsum sharded on
    # the query-head axis. Train/prefill only (decode keeps grouped form).
    expand_kv: bool = False

    @property
    def vocab_padded(self) -> int:
        v, p = self.vocab, self.vocab_pad_to
        return ((v + p - 1) // p) * p

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def ff_mult(self) -> int:
        return 2 if self.act in ("swiglu", "geglu") else 1

    def param_count(self) -> int:
        d, f, L = self.d_model, self.d_ff, self.n_layers
        attn = d * self.n_heads * self.d_head * 2 + d * self.n_kv * self.d_head * 2
        if self.is_moe:
            ffn = self.n_experts * (d * f * self.ff_mult + f * d) + d * self.n_experts
        else:
            ffn = d * f * self.ff_mult + f * d
        return L * (attn + ffn + 2 * d) + 2 * self.vocab_padded * d + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.n_layers
        attn = d * self.n_heads * self.d_head * 2 + d * self.n_kv * self.d_head * 2
        ffn = self.top_k * (d * f * self.ff_mult + f * d) + d * self.n_experts
        return L * (attn + ffn + 2 * d) + 2 * self.vocab_padded * d + d


# ---------------------------------------------------------------- params


def param_specs(cfg: TransformerConfig):
    d, dt = cfg.d_model, cfg.param_dtype
    L, H, Kv, Dh = cfg.n_layers, cfg.n_heads, cfg.n_kv, cfg.d_head

    layer: dict[str, ArraySpec] = {
        "ln1": ArraySpec((L, d), ("layers", None), dt, "ones"),
        "ln2": ArraySpec((L, d), ("layers", None), dt, "ones"),
        "wq": ArraySpec((L, d, H, Dh), ("layers", "embed", "heads", None), dt),
        "wk": ArraySpec((L, d, Kv, Dh), ("layers", "embed", "kv_heads", None), dt),
        "wv": ArraySpec((L, d, Kv, Dh), ("layers", "embed", "kv_heads", None), dt),
        "wo": ArraySpec((L, H, Dh, d), ("layers", "heads", None, "embed"), dt),
    }
    if cfg.is_moe:
        F = cfg.expert_fold
        assert cfg.d_ff % F == 0 and (cfg.d_ff * cfg.ff_mult) % F == 0
        layer |= {
            "router": ArraySpec((L, d, cfg.n_experts), ("layers", "embed", None), jnp.float32),
            "w1": ArraySpec(
                (L, cfg.n_experts * F, d, cfg.d_ff * cfg.ff_mult // F),
                ("layers", "expert", "embed", "expert_mlp"),
                dt,
            ),
            "w2": ArraySpec(
                (L, cfg.n_experts * F, cfg.d_ff // F, d),
                ("layers", "expert", "expert_mlp", "embed"),
                dt,
            ),
        }
    else:
        layer |= {
            "w1": ArraySpec((L, d, cfg.d_ff * cfg.ff_mult), ("layers", "embed", "mlp"), dt),
            "w2": ArraySpec((L, cfg.d_ff, d), ("layers", "mlp", "embed"), dt),
        }
    return {
        "embed": ArraySpec((cfg.vocab_padded, d), ("vocab", "embed"), dt, "embed", 1.0),
        "layers": layer,
        "ln_f": ArraySpec((d,), (None,), dt, "ones"),
        "lm_head": ArraySpec((d, cfg.vocab_padded), ("embed", "vocab"), dt),
    }


# ---------------------------------------------------------------- layers


def rmsnorm(x, scale, eps):
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * inv).astype(x.dtype) * scale


def rope(x, positions, theta):
    """x: [..., S, H, D]; positions broadcastable [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (np.log(theta) / half))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _activate(h, act):
    if act in ("swiglu", "geglu"):
        g, u = jnp.split(h, 2, axis=-1)
        gate = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        return gate * u
    return jax.nn.gelu(h)


def _loop(body, xs_list, cfg: TransformerConfig, checkpoint: bool):
    """Unrollable scan over leading axis of each tree in xs_list."""
    fn = jax.checkpoint(body) if (checkpoint and cfg.remat) else body
    n = jax.tree_util.tree_leaves(xs_list[0])[0].shape[0]
    if cfg.unroll:
        outs = []
        for i in range(n):
            args = [jax.tree_util.tree_map(lambda a: a[i], xs) for xs in xs_list]
            outs.append(fn(*args))
        return jax.tree_util.tree_map(lambda *ys: jnp.stack(ys), *outs)
    def scan_body(_, args):
        return None, fn(*args)
    _, outs = jax.lax.scan(scan_body, None, tuple(xs_list))
    return outs


def attention(q, k, v, cfg: TransformerConfig, causal: bool = True):
    """Query-chunked attention; per-chunk remat; no [S, S] global tensor.

    q: [B, S, Hq, D], k/v: [B, S, Hk, D] with Hq = Hk * G.

    Two parallelism regimes:
      * heads shard the model axis (attn_par=1): a sequential loop over
        query chunks; each step's [B, c, H_loc, S] score block is the only
        attention transient;
      * heads replicated (e.g. 36 heads on a 16-wide axis): ``attn_par``
        chunks are batched into one einsum with the chunk dim sharded over
        the model axis ("model_seq") — sequence-parallel attention — and
        an outer loop bounds memory.

    The masked upper triangle costs ~2x attention FLOPs; see EXPERIMENTS
    §Perf for the block-skipping variant trade-off.
    """
    B, S, Hq, D = q.shape
    Hk = k.shape[2]
    G = Hq // Hk
    c = min(cfg.attn_chunk, S)
    if S % c:  # ragged tail (odd prompt lengths): single full-S chunk
        c = S
    nq = S // c
    par = max(1, min(cfg.attn_par, nq))
    while nq % par:
        par -= 1
    n_outer = nq // par
    # par is the *leading* factor of the seq split so a ("dp","model",...)
    # seq-sharded q maps onto the par dim with zero resharding; k/v are
    # explicitly replicated over the model axis (the seq-parallel
    # all-gather), otherwise the einsum fights two shardings and XLA
    # emits all-to-alls (observed: 2.3 GiB/layer before this fix).
    qc = q.reshape(B, par, n_outer, c, Hq, D)
    if par > 1:
        qc = constrain(qc, "dp", "model_seq", None, None, None, None)
        k = constrain(k, "dp", None, None, None)
        v = constrain(v, "dp", None, None, None)
    qc = jnp.moveaxis(qc, 2, 0)  # [n_outer, B, par, c, Hq, D]
    scale = 1.0 / np.sqrt(D)
    kpos = jnp.arange(S)

    def qstep(i, qi):
        qg = qi.reshape(B, par, c, Hk, G, D)
        s = jnp.einsum("bpchgd,bkhd->bphgck", qg, k,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = (jnp.arange(par)[:, None] * n_outer + i) * c + jnp.arange(c)[None, :]
            mask = qpos[..., None] >= kpos  # [par, c, S]
            s = jnp.where(mask[None, :, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bphgck,bkhd->bpchgd", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, par, c, Hq, D).astype(q.dtype)

    outs = _loop(qstep, [jnp.arange(n_outer), qc], cfg, checkpoint=True)
    # [n_outer, B, par, c, Hq, D] -> [B, par, n_outer, c, Hq, D] -> flat S
    return jnp.moveaxis(outs, 0, 2).reshape(B, S, Hq, D)


def _moe_ffn(x, router_w, w1, w2, cfg: TransformerConfig):
    """x: [T, d] -> [T, d]. Group-local capacity dispatch, EP/TP-shardable.

    Tokens split into ``moe_groups`` groups (= DP shards in production);
    every dispatch op (one-hot, cumsum, scatter, gather) is *batched over
    the group dim*, which shards over dp — so dispatch never leaves the
    device and the only cross-device movement is the expert einsum's
    EP all-to-all / TP weight traffic. Per-group capacity (standard MoE
    semantics). Ungrouped (G=1) is the faithful global-priority variant.
    """
    T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    G = max(1, min(cfg.moe_groups, T))
    assert T % G == 0, (T, G)
    Tl = T // G
    C = int(np.ceil(Tl * k * cfg.capacity_factor / E))
    C = ((C + 7) // 8) * 8
    xg = constrain(x.reshape(G, Tl, d), "dp", None, None)
    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), router_w
    )  # [G, Tl, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eid = jax.lax.top_k(probs, k)  # [G, Tl, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    eid_f = eid.reshape(G, Tl * k)
    gate_f = gate.reshape(G, Tl * k)
    oh = jax.nn.one_hot(eid_f, E, dtype=jnp.int32)  # [G, Tl*k, E]
    pos = (jnp.cumsum(oh, axis=1) * oh).sum(-1) - 1  # position within expert
    keep = pos < C
    slot = jnp.where(keep, eid_f * C + jnp.clip(pos, 0, C - 1), E * C)
    tok = jnp.repeat(jnp.arange(Tl), k)[None].repeat(G, 0)  # [G, Tl*k]
    xt = jnp.take_along_axis(xg, tok[..., None], axis=1)  # [G, Tl*k, d]
    disp = jax.vmap(
        lambda data, ids: jax.ops.segment_sum(data, ids, num_segments=E * C + 1)
    )(jnp.where(keep[..., None], xt, 0), slot)[:, : E * C]
    # the scatter is dp-local by construction; pin it so its vjp stays local
    disp = constrain(disp, "dp", None, None)
    buf = disp.reshape(G, E, C, d).astype(cfg.param_dtype)
    F = cfg.expert_fold
    if F > 1:  # EPxTP: every fold of an expert sees the same tokens
        buf = jnp.repeat(buf, F, axis=1)  # [G, E*F, C, d]
    buf = constrain(buf, "dp", "expert", None, None)
    h = jnp.einsum("gecd,edf->gecf", buf, w1)
    h = constrain(h, "dp", "expert", None, "expert_mlp")
    h = _activate(h, cfg.act)
    out_buf = jnp.einsum("gecf,efd->gecd", h, w2)
    out_buf = constrain(out_buf, "dp", "expert", None, None)
    if F > 1:  # block-diagonal FFN decomposition: sum fold partials
        out_buf = out_buf.reshape(G, E, F, C, d).sum(2)
    # combine gathers from a dp-local (model-replicated) bf16 buffer: one
    # clean all-gather instead of f32 scatter all-reduces in the bwd
    out_flat = constrain(out_buf.reshape(G, E * C, d), "dp", None, None)
    picked = jnp.take_along_axis(
        out_flat, jnp.clip(slot, 0, E * C - 1)[..., None], axis=1
    )  # [G, Tl*k, d]
    picked = jnp.where(keep[..., None], picked, 0)
    combined = jax.vmap(
        lambda data, ids: jax.ops.segment_sum(data, ids, num_segments=Tl)
    )(picked * gate_f[..., None].astype(picked.dtype), tok)
    combined = constrain(combined, "dp", None, None)
    return combined.reshape(T, d).astype(x.dtype)


def _qkv(h, lp, cfg: TransformerConfig, positions):
    B, S = h.shape[:2]
    q = rope(jnp.einsum("bsd,dhk->bshk", h, lp["wq"]), positions, cfg.rope_theta)
    kk = rope(jnp.einsum("bsd,dhk->bshk", h, lp["wk"]), positions, cfg.rope_theta)
    vv = jnp.einsum("bsd,dhk->bshk", h, lp["wv"])
    if cfg.attn_par > 1 and S > 1:
        # keep the projections seq-sharded so the seq-parallel all-gather
        # in attention() moves *results*, not redundant compute
        q = constrain(q, "dp", "model_seq", None, None)
        kk = constrain(kk, "dp", "model_seq", None, None)
        vv = constrain(vv, "dp", "model_seq", None, None)
    return q, kk, vv


def _layer(x, lp, cfg: TransformerConfig, positions):
    B, S, d = x.shape
    G = cfg.n_heads // cfg.n_kv
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    q, kk, vv = _qkv(h, lp, cfg, positions)
    if cfg.expand_kv and G > 1:
        kk = jnp.repeat(kk, G, axis=2)  # [B, S, H, D] — shardable on H
        vv = jnp.repeat(vv, G, axis=2)
    attn = attention(q, kk, vv, cfg)
    x = x + jnp.einsum("bshk,hkd->bsd", attn, lp["wo"]).astype(x.dtype)
    h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.is_moe:
        out = _moe_ffn(h2.reshape(B * S, d), lp["router"], lp["w1"], lp["w2"], cfg)
        out = out.reshape(B, S, d)
    else:
        out = _activate(h2 @ lp["w1"], cfg.act) @ lp["w2"]
    return x + out.astype(x.dtype)


def _run_layers(params, x, positions, cfg: TransformerConfig, collect_kv: bool = False):
    def one(x, lp):
        y = _layer(x, lp, cfg, positions)
        # layer-boundary carry sharding: seq for replicated-head archs
        # (feeds their seq-parallel attention), feature-dim otherwise —
        # keeps the remat-saved carry at 1/16 size without the seq<->head
        # resharding ping-pong (EXPERIMENTS §Perf A-1)
        y = constrain(y, "dp", "model_seq", "model_d")
        if collect_kv:
            h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
            _, kk, vv = _qkv(h, lp, cfg, positions)
            return y, (kk.astype(cfg.param_dtype), vv.astype(cfg.param_dtype))
        return y, None

    body = jax.checkpoint(one) if cfg.remat else one
    if cfg.unroll:
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, kv = body(x, lp)
            kvs.append(kv)
        kv_out = (
            jax.tree_util.tree_map(lambda *ys: jnp.stack(ys), *kvs)
            if collect_kv
            else None
        )
        return x, kv_out
    x, kv_out = jax.lax.scan(body, x, params["layers"])
    return x, kv_out


def backbone(params, tokens, cfg: TransformerConfig):
    """tokens [B, S] -> final hidden [B, S, d]."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "dp", "model_seq", "model_d")
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model)
    positions = jnp.arange(S)[None, :]
    x, _ = _run_layers(params, x, positions, cfg)
    return rmsnorm(x, params["ln_f"], cfg.norm_eps)


def loss_fn(params, tokens, cfg: TransformerConfig):
    """Next-token cross entropy, head computed in sequence chunks."""
    B, S = tokens.shape
    h = backbone(params, tokens, cfg)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.concatenate(
        [jnp.ones((B, S - 1), bool), jnp.zeros((B, 1), bool)], axis=1
    )
    c = min(cfg.loss_chunk, S)
    nchunk = S // c
    hc = jnp.moveaxis(h.reshape(B, nchunk, c, -1), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nchunk, c), 1, 0)
    mc = jnp.moveaxis(mask.reshape(B, nchunk, c), 1, 0)

    def chunk_nll(hh, ll, mm):
        logits = (hh @ params["lm_head"]).astype(jnp.float32)
        if cfg.logit_softcap:
            logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return jnp.where(mm, lse - gold, 0.0).sum()

    nlls = _loop(chunk_nll, [hc, lc, mc], cfg, checkpoint=True)
    return nlls.sum() / jnp.maximum(mask.sum(), 1)


# ---------------------------------------------------------------- decode


def kv_cache_specs(cfg: TransformerConfig, batch: int, max_len: int):
    dt = cfg.param_dtype
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv, cfg.d_head)
    logical = ("layers", "cache_batch", "seq", "kv_heads", None)
    return {
        "k": ArraySpec(shape, logical, dt, "zeros"),
        "v": ArraySpec(shape, logical, dt, "zeros"),
    }


def prefill(params, tokens, cfg: TransformerConfig):
    """Build the KV cache for a prompt; returns (cache, last hidden)."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    x = constrain(x, "dp", "model_seq", "model_d")
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model)
    positions = jnp.arange(S)[None, :]
    x, (ks, vs) = _run_layers(params, x, positions, cfg, collect_kv=True)
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    return {"k": ks, "v": vs}, x[:, -1]


def decode_step(params, cache, token, cache_len, cfg: TransformerConfig):
    """One decode step. token [B] int32; cache_len scalar int32.

    Attention runs over the full (padded) cache with a length mask —
    sequence-sharded caches combine via XLA's partial-softmax collectives.
    Returns (logits [B, V], new k/v slices [L, B, 1, Kv, D]).
    """
    B = token.shape[0]
    S_max = cache["k"].shape[2]
    x = jnp.take(params["embed"], token, axis=0)[:, None]  # [B, 1, d]
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model)
    pos = jnp.full((B, 1), cache_len, jnp.int32)
    # slots [0, cache_len) + the virtual self slot at index S_max
    lmask = (jnp.arange(S_max + 1)[None, :] < cache_len).at[:, S_max].set(True)

    def one_layer(x, lp, kcache, vcache):
        Bq, _, d = x.shape
        G = cfg.n_heads // cfg.n_kv
        h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, kk, vv = _qkv(h, lp, cfg, pos)
        qg = q.reshape(Bq, 1, cfg.n_kv, G, cfg.d_head)
        # the current token attends to the cache AND to itself: its k/v
        # ride along as a virtual cache slot S_max (committed by the caller)
        kc = jnp.concatenate([kcache, kk.astype(kcache.dtype)], axis=1)
        vc = jnp.concatenate([vcache, vv.astype(vcache.dtype)], axis=1)
        s = jnp.einsum(
            "bqhgd,bshd->bhgqs", qg, kc, preferred_element_type=jnp.float32
        ) / np.sqrt(cfg.d_head)
        s = jnp.where(lmask[:, None, None, None, :], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        attn = jnp.einsum(
            "bhgqs,bshd->bqhgd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32,
        ).reshape(Bq, 1, cfg.n_heads, cfg.d_head)
        x = x + jnp.einsum("bshk,hkd->bsd", attn.astype(x.dtype), lp["wo"])
        h2 = rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.is_moe:
            out = _moe_ffn(h2.reshape(Bq, d), lp["router"], lp["w1"], lp["w2"], cfg)
            out = out[:, None]
        else:
            out = _activate(h2 @ lp["w1"], cfg.act) @ lp["w2"]
        return x + out.astype(x.dtype), (
            kk.astype(cfg.param_dtype),
            vv.astype(cfg.param_dtype),
        )

    if cfg.unroll:
        kvs = []
        for i in range(cfg.n_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["layers"])
            x, kv = one_layer(x, lp, cache["k"][i], cache["v"][i])
            kvs.append(kv)
        knew, vnew = jax.tree_util.tree_map(lambda *ys: jnp.stack(ys), *kvs)
    else:
        def body(x, lpkv):
            lp, kc, vc = lpkv
            return one_layer(x, lp, kc, vc)

        x, (knew, vnew) = jax.lax.scan(
            body, x, (params["layers"], cache["k"], cache["v"])
        )
    x = rmsnorm(x, params["ln_f"], cfg.norm_eps)
    logits = (x[:, 0] @ params["lm_head"]).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, (knew, vnew)
