"""EmbeddingBag for JAX — gather + segment-reduce (no native op exists).

table [V, D] row-shardable over the model axis; lookups via jnp.take.
Bags are (ids [B, bag], weights?) -> pooled [B, D].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table, ids, mode: str = "sum", weights=None, valid=None):
    """table [V, D]; ids int32 [B, bag]; valid bool [B, bag] masks padding."""
    B, bag = ids.shape
    emb = jnp.take(table, ids.reshape(-1), axis=0).reshape(B, bag, -1)
    if weights is not None:
        emb = emb * weights[..., None].astype(emb.dtype)
    if valid is not None:
        emb = jnp.where(valid[..., None], emb, 0)
    if mode == "sum":
        return emb.sum(axis=1)
    if mode == "mean":
        denom = (
            valid.sum(axis=1, keepdims=True).astype(emb.dtype)
            if valid is not None
            else jnp.full((B, 1), bag, emb.dtype)
        )
        return emb.sum(axis=1) / jnp.maximum(denom, 1)
    if mode == "max":
        neg = jnp.finfo(emb.dtype).min
        if valid is not None:
            emb = jnp.where(valid[..., None], emb, neg)
        return emb.max(axis=1)
    raise ValueError(mode)


def embedding_bag_ragged(table, flat_ids, segment_ids, num_bags: int,
                         mode: str = "sum"):
    """Ragged variant: flat_ids [T], segment_ids [T] -> [num_bags, D]."""
    emb = jnp.take(table, flat_ids, axis=0)
    if mode == "sum":
        return jax.ops.segment_sum(emb, segment_ids, num_segments=num_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(emb, segment_ids, num_segments=num_bags)
        c = jax.ops.segment_sum(
            jnp.ones_like(flat_ids, emb.dtype), segment_ids, num_segments=num_bags
        )
        return s / jnp.maximum(c[:, None], 1)
    if mode == "max":
        return jax.ops.segment_max(emb, segment_ids, num_segments=num_bags)
    raise ValueError(mode)
