"""GIN (Graph Isomorphism Network) — arXiv:1810.00826.

h_i' = MLP_k((1 + eps_k) * h_i + sum_{j in N(i)} h_j), learnable eps.
n_layers=5, d_hidden=64, sum aggregator (assigned config).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.param import ArraySpec
from repro.distributed.sharding import constrain
from repro.models.gnn_common import GraphBatch, mlp_specs, mlp_apply, chunked_edge_aggregate


@dataclasses.dataclass(frozen=True)
class GINConfig:
    name: str = "gin-tu"
    n_layers: int = 5
    d_hidden: int = 64
    d_in: int = 64
    n_classes: int = 40
    readout: str = "none"  # none (node-level) | sum (graph-level)
    edge_chunk: int = 0
    unroll: bool = False
    dtype: Any = jnp.float32


def param_specs(cfg: GINConfig):
    specs = {
        "proj": mlp_specs((cfg.d_in, cfg.d_hidden), cfg.dtype),
        "eps": ArraySpec((cfg.n_layers,), (None,), cfg.dtype, "zeros"),
        "layers": [
            mlp_specs((cfg.d_hidden, cfg.d_hidden, cfg.d_hidden), cfg.dtype)
            for _ in range(cfg.n_layers)
        ],
        "head": mlp_specs((cfg.d_hidden, cfg.n_classes), cfg.dtype),
    }
    return specs


def forward(params, batch: GraphBatch, cfg: GINConfig):
    h = mlp_apply(params["proj"], batch.node_feats.astype(cfg.dtype))
    h = jnp.where(batch.node_mask[:, None], h, 0)
    for k in range(cfg.n_layers):
        agg = chunked_edge_aggregate(
            lambda s, d, m: h[s],
            batch.src, batch.dst, batch.edge_mask, batch.n,
            cfg.d_hidden, cfg.edge_chunk, cfg.dtype, cfg.unroll,
        )
        h = mlp_apply(params["layers"][k], (1.0 + params["eps"][k]) * h + agg,
                      layernorm=True)
        h = constrain(jnp.where(batch.node_mask[:, None], h, 0), "nodes", None)
    return mlp_apply(params["head"], h)


def graph_logits(params, batch: GraphBatch, cfg: GINConfig, n_graphs: int):
    h = mlp_apply(params["proj"], batch.node_feats.astype(cfg.dtype))
    h = jnp.where(batch.node_mask[:, None], h, 0)
    for k in range(cfg.n_layers):
        agg = chunked_edge_aggregate(
            lambda s, d, m: h[s],
            batch.src, batch.dst, batch.edge_mask, batch.n,
            cfg.d_hidden, cfg.edge_chunk, cfg.dtype, cfg.unroll,
        )
        h = mlp_apply(params["layers"][k], (1.0 + params["eps"][k]) * h + agg,
                      layernorm=True)
        h = jnp.where(batch.node_mask[:, None], h, 0)
    pooled = jax.ops.segment_sum(h, batch.graph_ids, num_segments=n_graphs)
    return mlp_apply(params["head"], pooled)


def loss_fn(params, batch: GraphBatch, cfg: GINConfig):
    logits = forward(params, batch, cfg).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch.labels[:, None], axis=-1)[:, 0]
    nll = jnp.where(batch.label_mask, lse - gold, 0.0)
    return nll.sum() / jnp.maximum(batch.label_mask.sum(), 1)
