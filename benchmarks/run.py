"""Benchmark harness: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run`` prints
``name,us_per_call,derived`` CSV rows.
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()

    from benchmarks import (
        bench_throughput,
        fig6_size_scaling,
        fig7_real_graphs,
        fig8_parallel_scaling,
        fig9_approximation,
        fig10_blocking,
        fig11_substreams,
        table6_memory,
        roofline_report,
    )

    suites = [
        ("fig6", fig6_size_scaling),
        ("fig7", fig7_real_graphs),
        ("fig8", fig8_parallel_scaling),
        ("fig9", fig9_approximation),
        ("fig10", fig10_blocking),
        ("fig11", fig11_substreams),
        ("table6", table6_memory),
        ("roofline", roofline_report),
        ("throughput", bench_throughput),
    ]
    print("name,us_per_call,derived")
    failed = 0
    for name, mod in suites:
        if args.only and args.only not in name:
            continue
        try:
            for row in mod.run():
                print(f"{row[0]},{row[1]:.1f},{row[2]}")
        except Exception:
            failed += 1
            traceback.print_exc()
            print(f"{name},nan,ERROR")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
