"""Fig. 10: influence of epoch size K. Paper: SC-OPT 125->175M e/s as K
grows (fewer epoch stalls, more matching-bit sharing), flattening by K=256.
Here K changes the lexicographic order (reuse locality) and we also report
the model-level epoch count + DRAM-traffic estimate from the paper's
cost model (§4.2.4: v-bit transfers shrink n -> n/K)."""
from benchmarks.common import make_workload, timed
from repro.core import mwm_blocked


def run(scale=12, L=16, eps=0.1):
    rows = []
    stream, cfg = make_workload(scale, 16, L, eps)
    m = int(stream.valid.sum())
    n = cfg.n
    for K in (1, 8, 32, 128, 256):
        dt, _ = timed(lambda: mwm_blocked(stream, cfg, K=K))
        epochs = -(-n // K)
        # §4.2.4 model: v-bit chunk traffic n/K + per-edge stream traffic
        vbit_chunks = epochs + m / 8
        rows.append(
            (
                f"fig10/blocked/K={K}",
                dt * 1e6,
                f"{m/dt/1e6:.2f}Me/s;reads/edge={vbit_chunks/m + 1/8:.3f}",
            )
        )
    return rows
