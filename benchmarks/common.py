"""Benchmark utilities. Every benchmark returns rows
(name, us_per_call, derived) and benchmarks/run.py prints them as CSV.

CPU wall-times here are *sanity numbers* — the performance claims live in
EXPERIMENTS.md §Roofline (dry-run derived). Sizes are scaled down from the
paper's 2^16..2^21 Kronecker graphs to keep the suite minutes-long on one
CPU core; the scaling *trends* (the figures' shapes) are what is checked.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import EdgeStream, SubstreamConfig
from repro.graph.generators import kronecker_graph, uniform_weights


def timed(fn, *args, reps: int = 3, warmup: int = 1, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") or isinstance(
            out, jax.Array
        ) else None
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        try:
            jax.tree_util.tree_map(
                lambda x: x.block_until_ready() if isinstance(x, jax.Array) else x, out
            )
        except Exception:
            pass
        ts.append(time.perf_counter() - t0)
    return min(ts), out


def make_workload(scale: int, edge_factor: int, L: int, eps: float, seed: int = 0):
    src, dst = kronecker_graph(scale, edge_factor, seed=seed)
    w = uniform_weights(len(src), L, eps, seed=seed)
    n = 1 << scale
    cfg = SubstreamConfig(n=n, L=L, eps=eps)
    stream = EdgeStream.from_numpy(src, dst, w)
    return stream, cfg
