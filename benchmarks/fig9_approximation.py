"""Fig. 9: approximation quality vs exact MWM (networkx blossom oracle).

Paper: SC accuracy within ~3% of G-SEQ; both near-exact in practice,
far better than the 4+eps / 2+eps bounds."""
from benchmarks.common import timed
from repro.core import (
    EdgeStream,
    SubstreamConfig,
    exact_mwm_weight,
    gseq,
    matching_weight,
    mwm_pipeline,
)
from repro.graph.generators import kronecker_graph, uniform_weights


def run(scale=7, eps_list=(0.05, 0.1, 0.3, 0.6)):
    rows = []
    src, dst = kronecker_graph(scale, 8, seed=5)
    for eps in eps_list:
        L = 32
        w = uniform_weights(len(src), L, eps, seed=5)
        cfg = SubstreamConfig(n=1 << scale, L=L, eps=eps)
        stream = EdgeStream.from_numpy(src, dst, w)
        exact = exact_mwm_weight(stream)
        dt, (_, wgt) = timed(lambda: mwm_pipeline(stream, cfg), reps=1)
        gi = gseq(stream, cfg.n, eps)
        gw = matching_weight(stream, gi)
        rows.append(
            (f"fig9/sc/eps={eps}", dt * 1e6, f"ratio={exact/max(wgt,1e-9):.4f}")
        )
        rows.append((f"fig9/gseq/eps={eps}", 0.0, f"ratio={exact/max(gw,1e-9):.4f}"))
    return rows
