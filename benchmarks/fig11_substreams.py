"""Fig. 11: influence of substream count L. Paper: CS-SEQ degrades ~1/L,
SC-OPT stays ~140M e/s because L rides the bit-parallel (here: lane) axis.
The lane-parallel analogue is the vectorized scan/rounds: time should grow
far slower than L."""
from benchmarks.common import make_workload, timed
from repro.core import SubstreamConfig, mwm_rounds, mwm_scan


def run(scale=11, eps_by_L=None):
    eps_by_L = eps_by_L or {1: 0.6, 8: 0.6, 32: 0.6, 64: 0.1, 128: 0.1}
    rows = []
    for L, eps in eps_by_L.items():
        stream, _ = make_workload(scale, 16, L, eps)
        cfg = SubstreamConfig(n=1 << scale, L=L, eps=eps)
        m = int(stream.valid.sum())
        dt, _ = timed(lambda: mwm_scan(stream, cfg))
        rows.append((f"fig11/scan/L={L}", dt * 1e6, f"{m/dt/1e6:.2f}Me/s"))
        dt, _ = timed(lambda: mwm_rounds(stream, cfg))
        rows.append((f"fig11/rounds/L={L}", dt * 1e6, f"{m/dt/1e6:.2f}Me/s"))
    return rows
