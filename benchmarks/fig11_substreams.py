"""Fig. 11: influence of substream count L. Paper: CS-SEQ degrades ~1/L,
SC-OPT stays ~140M e/s because L rides the bit-parallel (here: lane) axis.
The lane-parallel analogue is the vectorized scan/rounds: time should grow
far slower than L. Each L also reports the VMEM bit-block footprint of
the packed vs unpacked layout — the §4.3 storage curve: packed bytes per
vertex grow with ceil(L/8) while the unpacked layout pays max(L, 128)."""
from benchmarks.common import make_workload, timed
from repro.core import SubstreamConfig, mwm_rounds, mwm_scan
from repro.kernels.substream_match.ops import max_vertices, vmem_plan


def run(scale=11, eps_by_L=None):
    eps_by_L = eps_by_L or {1: 0.6, 8: 0.6, 32: 0.6, 64: 0.1, 128: 0.1}
    rows = []
    for L, eps in eps_by_L.items():
        stream, _ = make_workload(scale, 16, L, eps)
        cfg = SubstreamConfig(n=1 << scale, L=L, eps=eps)
        m = int(stream.valid.sum())
        dt, _ = timed(lambda: mwm_scan(stream, cfg))
        rows.append((f"fig11/scan/L={L}", dt * 1e6, f"{m/dt/1e6:.2f}Me/s"))
        dt, _ = timed(lambda: mwm_rounds(stream, cfg))
        rows.append((f"fig11/rounds/L={L}", dt * 1e6, f"{m/dt/1e6:.2f}Me/s"))
        packed = vmem_plan(cfg.n, L, packed=True)
        unpacked = vmem_plan(cfg.n, L, packed=False)
        rows.append(
            (
                f"fig11/vmem/L={L}",
                0.0,
                f"packed={packed.bytes_per_vertex}B/v "
                f"unpacked={unpacked.bytes_per_vertex}B/v "
                f"capacity={max_vertices(L)}v "
                f"({max_vertices(L)/max_vertices(L, packed=False):.0f}x)",
            )
        )
    return rows
