"""Fig. 8: strong scaling with parallelism T. The CPU analogue of CS-PAR's
threads is substream-sharded work: we vary the substream count processed
per pass and measure per-substream throughput of the rounds matcher
(vectorized over L on the VPU lanes — the FPGA's bit-parallel dimension)."""
from benchmarks.common import make_workload, timed
from repro.core import SubstreamConfig, mwm_rounds


def run(scale=12, eps=0.1):
    rows = []
    stream, _ = make_workload(scale, 16, 64, eps)
    m = int(stream.valid.sum())
    for L in (1, 4, 16, 64):
        cfg = SubstreamConfig(n=1 << scale, L=L, eps=eps)
        dt, _ = timed(lambda: mwm_rounds(stream, cfg))
        rows.append(
            (f"fig8/rounds/L={L}", dt * 1e6, f"{m*L/dt/1e6:.2f}M(edge*sub)/s")
        )
    return rows
