"""Fig. 6: throughput (edges/s) vs graph size, Kronecker power-law.

Paper: CS-SEQ ~3M e/s flat, SC-OPT 135->140M e/s (FPGA). Here the CPU
wall-clock analogue compares the same algorithm variants; the roofline
(EXPERIMENTS §Roofline) carries the TPU projection.
"""
from benchmarks.common import make_workload, timed
from repro.core import mwm_blocked, mwm_rounds, mwm_scan


def run(scales=(10, 12, 14), L=16, eps=0.1):
    rows = []
    for scale in scales:
        stream, cfg = make_workload(scale, 16, L, eps)
        m = int(stream.valid.sum())
        for name, fn in [
            ("cs_seq_scan", lambda: mwm_scan(stream, cfg)),
            ("sc_blocked", lambda: mwm_blocked(stream, cfg, K=32)),
            ("sc_parallel_rounds", lambda: mwm_rounds(stream, cfg)),
        ]:
            dt, _ = timed(fn)
            rows.append(
                (f"fig6/{name}/2^{scale}", dt * 1e6, f"{m/dt/1e6:.2f}Me/s")
            )
    return rows
