"""Part-1 throughput: edges/sec per engine, the repo's perf trajectory.

Compares the six Part-1 engines on Kronecker workloads:

* ``scan``         — the CS-SEQ `lax.scan` oracle (1 edge / step);
* ``pallas_edges`` — the paper-literal Pallas pipeline (1 edge / iter);
* ``pallas_waves`` — the segment-vectorized Pallas pipeline (fill-packed
  slot layout, one [SEG, width] row-addressed tile per trip;
  `schedule="waves"`);
* ``pallas_mega``  — the grid-pipelined segment megakernel
  (`schedule="mega"`: scalar-prefetched block-aligned layout,
  ``seg_block`` segments per tile op, double-buffered tile stream);
* ``waves_xla``    — the XLA wave reference (`mwm_waves`);
* ``rounds``       — the propose–accept fixed point (`mwm_rounds`).

Besides the CSV rows every benchmark emits, this one writes
``BENCH_substream.json`` at the repo root — the measured perf record the
acceptance gate reads (wave vs per-edge speedup, mega vs the XLA oracle,
fill, #waves/#segments, scheduler/pack seconds per graph). Every engine
row additionally carries its telemetry block (``stage_seconds`` —
schedule/pack/layout/compile/execute — and the plan/schedule
``counters``), captured by one instrumented cold call + one instrumented
steady call around the disabled-telemetry timed reps; ``--trace out.json``
dumps those instrumented calls as Chrome trace-event JSON for Perfetto.
``--check`` runs :func:`check_report` over the record and exits non-zero
with the violated gates named — never an assert, so CI logs the reason.
The Pallas engines run with ``on_plan_failure="fallback"`` (the guarded
production configuration); each graph also embeds a strict
``validate_stream`` guard record, and the gate requires zero validation
drops and ``fallback.count == 0`` on every Pallas row, so a benchmark
number can never secretly come from a degraded engine. The wave
schedule is built once per graph on the host and its cost reported
separately (it is reusable across L/eps sweeps and engine runs, like the
§4.2 lexicographic pre-sort the paper already assumes); the mega engine
timing still re-pads it block-aligned per call (its own host cost).
Each graph further embeds a ``recovery`` block from the resumable path
(``match_epochs``: producer stall of per-epoch async snapshots relative
to the chunked run without them → ``snapshot_overhead_pct``; a faultline
kill mid-stream + timed cold resume → ``recover_seconds``;
``resumed_bit_exact`` vs a one-shot run; ``clean_retries`` from a
guarded clean run), gated by gate 7.

Scale 14 (n = 16384) covers the VMEM-pressure point where the former
one-wave-one-tile kernel paid O(n·width) whole-block rematerialization
per wave and padded every wave to the hub width (fill ~0.02 there); the
sequential engines are measured with fewer reps at that size to keep the
suite minutes-long.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import shutil
import sys
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import make_workload, timed
from repro import obs
from repro.checkpoint import SnapshotManager
from repro.core import ExecutionGuard, mwm_rounds, mwm_scan, validate_stream
from repro.core.matching import mwm_waves
from repro.distributed import StragglerMonitor
from repro.graph.waves import block_aligned_layout, wave_schedule
from repro.kernels.substream_match.ops import (
    MEGA_SEG_BLOCK,
    match_epochs,
    mega_plan,
    substream_match,
    traffic_bytes,
    wave_plan,
)
from repro.testing import faultline

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_substream.json"

#: Acceptance gates (checked by --check, e.g. from CI on the scale-10
#: graph): wave Pallas must beat per-edge Pallas by this factor in
#: edges/sec, the packed schedule must keep at least this fill, and the
#: megakernel must match or beat the plain-XLA wave oracle (the raised
#: gate of ISSUE 6 — a Pallas pipeline slower than naive XLA is a bug).
TARGET_SPEEDUP = 5.0
TARGET_FILL = 0.5
TARGET_MEGA_VS_XLA = 1.0
#: Gate 7 (ISSUE 9): per-epoch snapshotting may stall the producer by
#: at most this share of the same chunked run without snapshots, the
#: resumed-after-kill result must be bit-exact, and the clean path must
#: log zero retries.
TARGET_SNAPSHOT_OVERHEAD_PCT = 5.0

#: Epoch count of the recovery benchmark (the resumable production
#: configuration: mega engine, fallback cascade, guarded epochs).
RECOVERY_EPOCHS = 4

DEFAULT_SCALES = (10, 12, 14)
EDGE_FACTOR = 8
L = 32
EPS = 0.1

#: Engines that walk one edge per step; above this edge count they get a
#: single timed rep (compile + one steady call) so scale 14 stays
#: benchable.
SEQUENTIAL_ENGINES = ("scan", "pallas_edges")
SEQUENTIAL_REPS_CUTOFF = 50_000


def _instrumented_scan(stream, cfg, telemetry):
    """The scan oracle has no telemetry hook of its own (it is one jitted
    call with no host stages), so the bench instruments it externally."""
    rec = obs.recorder(
        telemetry, "scan", stream.num_edges, jax.default_backend()
    )
    key = ("scan", cfg.n, cfg.L, cfg.eps, stream.num_edges)
    if telemetry.enabled:
        rec.put("stream.num_edges", stream.num_edges)
    with rec.device_stage(key):
        out = mwm_scan(stream, cfg)
        rec.block(out)
    rec.finish()
    return out


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def _expected_counters(schedule, cfg, L: int) -> dict:
    """Recompute the plan accounting the wave/mega telemetry counters
    must reproduce bit-exactly — embedded in the report so
    :func:`check_report` can cross-check the emitted counters without
    re-running anything."""
    wplan = wave_plan(cfg.n, L, schedule)
    layout = block_aligned_layout(schedule, MEGA_SEG_BLOCK)
    mplan = mega_plan(cfg.n, L, layout)
    ns_pad = _round_up(max(schedule.num_segments, 1), wplan.block_s)
    mega_tiles_pad = _round_up(max(layout.num_tiles, 1), mplan.tiles_per_block)
    return {
        "pallas_waves": {
            "plan.gather_bytes": int(wplan.gather_bytes),
            "plan.bit_block_bytes": int(wplan.nbytes),
            "traffic.hbm_bytes": traffic_bytes(
                ns_pad * wplan.seg, schedule.num_scheduled, wplan.width
            ),
        },
        "pallas_mega": {
            "plan.gather_bytes": int(mplan.gather_bytes),
            "plan.bit_block_bytes": int(mplan.nbytes),
            "traffic.hbm_bytes": traffic_bytes(
                mega_tiles_pad * mplan.seg_block * mplan.seg,
                schedule.num_scheduled,
                mplan.width,
            ),
        },
    }


class _StallMeter:
    """SnapshotManager proxy that times producer-visible snapshot cost.

    ``save()`` is timed — with the async writer this is the host copy
    plus a bounded-queue enqueue, which is exactly the time the epoch
    loop is *blocked* on snapshotting (the stall a device-bound
    producer would also pay). ``wait()`` is a no-op during the timed
    window: the final writer drain is durability cost, not steady-state
    overhead, so it is timed separately (``flush_seconds``) via the
    real manager's ``wait()`` after the timed call returns.
    """

    def __init__(self, inner):
        self._inner = inner
        self.stall_seconds = 0.0

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def save(self, state):
        t0 = time.perf_counter()
        self._inner.save(state)
        self.stall_seconds += time.perf_counter() - t0

    def wait(self):
        pass


def _bench_recovery(stream, cfg, schedule, reps: int) -> dict:
    """Measure the resumable path: snapshot overhead, kill, recover.

    Protocol (all runs use the guarded production configuration — mega
    engine, fallback cascade, ``RECOVERY_EPOCHS`` epochs):

    1. one guarded clean run with live telemetry — ``clean_retries``
       must come out 0 (gate 7: the guard never fires on a clean path);
    2. ``reps`` timed chunked runs without snapshots (min) vs ``reps``
       timed chunked runs with per-epoch **async** snapshots, the final
       writer drain excluded and reported as ``flush_seconds``.
       ``snapshot_overhead_pct`` — the gated number — is the producer
       **stall**: the time the epoch loop is blocked inside ``save()``
       (host copy + bounded-queue enqueue; min over reps) as a share of
       the chunked baseline. The end-to-end wall delta is reported
       unguarded as ``chunked_snapshot_seconds``: on this CPU-interpret
       container the background writer competes with the GIL-bound host
       scheduler, so the wall delta overstates what a device-bound
       producer pays — the stall is the honest critical-path metric and
       still catches any regression that puts blocking IO back on the
       producer (a synchronous save or a per-epoch flush explodes it);
    3. a run killed after epoch ``kill_after_epoch`` via the faultline
       injector, then a timed cold resume from the snapshot directory —
       ``recover_seconds`` covers restore + replay of the suffix only;
    4. the resumed result is compared bit-for-bit against a one-shot
       run (``resumed_bit_exact``).
    """
    kw = dict(
        epochs=RECOVERY_EPOCHS, engine="mega", on_plan_failure="fallback"
    )
    # the recovery protocol is cheap (~2s/graph), so even a --reps 1 CI
    # run takes 3 timed reps here: the gated stall is a min-over-reps
    # statistic and a single sample would gate on scheduler noise
    reps = max(reps, 3)

    # 1. clean guarded run: warms every per-epoch jit variant and proves
    # the guard stays silent when nothing is injected
    tel = obs.Telemetry()
    guard = ExecutionGuard(
        retries=2, telemetry=tel, monitor=StragglerMonitor(warmup_steps=1)
    )
    clean = match_epochs(stream, cfg, guard=guard, telemetry=tel, **kw)
    jax.block_until_ready(clean.assigned)
    clean_retries = int(tel.counters.asdict().get("guard.retry", 0))

    # 2. chunked without snapshots vs chunked with async snapshots
    def plain():
        out = match_epochs(stream, cfg, **kw)
        jax.block_until_ready(out.assigned)
        return out

    t_plain, _ = timed(plain, reps=reps, warmup=0)

    snap_times: list[float] = []
    stall_times: list[float] = []
    flush_times: list[float] = []
    for _ in range(reps):
        snapdir = tempfile.mkdtemp(prefix="bench_recovery_")
        try:
            meter = _StallMeter(
                SnapshotManager(snapdir, keep=1, async_save=True)
            )
            t0 = time.perf_counter()
            out = match_epochs(stream, cfg, snapshots=meter, **kw)
            jax.block_until_ready(out.assigned)
            snap_times.append(time.perf_counter() - t0)
            stall_times.append(meter.stall_seconds)
            t0 = time.perf_counter()
            meter._inner.wait()
            flush_times.append(time.perf_counter() - t0)
        finally:
            shutil.rmtree(snapdir, ignore_errors=True)
    t_snap = min(snap_times)
    stall = min(stall_times)
    overhead_pct = stall / t_plain * 100.0

    # 3. kill mid-stream, then time the cold resume (restore + suffix)
    kill_after = RECOVERY_EPOCHS // 2 - 1  # half the stream durable
    snapdir = tempfile.mkdtemp(prefix="bench_recovery_kill_")
    try:
        snaps = SnapshotManager(snapdir, keep=1, async_save=True)
        try:
            match_epochs(
                stream, cfg, snapshots=snaps,
                epoch_hook=faultline.kill_at_epoch(kill_after), **kw
            )
        except faultline.SimulatedCrash:
            pass
        snaps.wait()  # the injector kills the epoch loop, not the writer
        t0 = time.perf_counter()
        resumed = match_epochs(
            stream, cfg,
            snapshots=SnapshotManager(snapdir, keep=1, async_save=True),
            **kw,
        )
        jax.block_until_ready(resumed.assigned)
        recover_seconds = time.perf_counter() - t0
    finally:
        shutil.rmtree(snapdir, ignore_errors=True)

    # 4. bit-exactness of the resumed run against a one-shot run
    oneshot = substream_match(
        stream, cfg, schedule="mega", waves=schedule,
        on_plan_failure="fallback",
    )
    resumed_bit_exact = bool(
        np.array_equal(np.asarray(resumed.assigned), np.asarray(oneshot.assigned))
        and np.array_equal(
            np.asarray(resumed.mb_packed if resumed.is_packed else resumed.mb),
            np.asarray(oneshot.mb_packed if oneshot.is_packed else oneshot.mb),
        )
    )
    return {
        "epochs": RECOVERY_EPOCHS,
        "engine": "mega",
        "chunked_seconds": t_plain,
        "chunked_snapshot_seconds": t_snap,
        "snapshot_stall_seconds": stall,
        "snapshot_overhead_pct": round(overhead_pct, 2),
        "flush_seconds": min(flush_times),
        "kill_after_epoch": kill_after,
        "recover_seconds": recover_seconds,
        "resumed_bit_exact": resumed_bit_exact,
        "clean_retries": clean_retries,
    }


def _bench_graph(
    scale: int, edge_factor: int, L: int, eps: float, reps: int, telemetry
):
    stream, cfg = make_workload(scale, edge_factor, L, eps)
    m = stream.num_edges

    # clean-path guard record: the bench workload must validate strictly
    # (a raise here means the generator regressed), and the report embeds
    # the guard counters so the gate can pin "no drops, no degradation"
    _, vreport = validate_stream(stream, cfg.n, policy="strict", telemetry=telemetry)
    validation = {"policy": vreport.policy, **vreport.counters()}

    schedule = wave_schedule(
        np.asarray(stream.src),
        np.asarray(stream.dst),
        valid=np.asarray(stream.valid),
        telemetry=telemetry,
    )

    engines = {
        "scan": lambda tel=obs.DISABLED: _instrumented_scan(stream, cfg, tel),
        "pallas_edges": lambda tel=obs.DISABLED: substream_match(
            stream, cfg, schedule="edges", telemetry=tel,
            on_plan_failure="fallback",
        ),
        "pallas_waves": lambda tel=obs.DISABLED: substream_match(
            stream, cfg, schedule="waves", waves=schedule, telemetry=tel,
            on_plan_failure="fallback",
        ),
        "pallas_mega": lambda tel=obs.DISABLED: substream_match(
            stream, cfg, schedule="mega", waves=schedule, telemetry=tel,
            on_plan_failure="fallback",
        ),
        "waves_xla": lambda tel=obs.DISABLED: mwm_waves(
            stream, cfg, schedule=schedule, telemetry=tel
        ),
        "rounds": lambda tel=obs.DISABLED: mwm_rounds(
            stream, cfg, telemetry=tel
        ),
    }
    timings = {}
    for name, fn in engines.items():
        r = reps
        seq_single = name in SEQUENTIAL_ENGINES and m > SEQUENTIAL_REPS_CUTOFF
        if seq_single:
            r = 1
        # measurement protocol: one instrumented cold call captures the
        # compile stage (and doubles as the warmup), the timed reps run
        # with telemetry DISABLED (so seconds_per_call stays the raw
        # engine speed), and one instrumented steady call captures the
        # execute/schedule/layout split. Sequential engines over the
        # cutoff reuse the steady instrumented call as their single
        # timed rep (telemetry overhead is noise at that call length).
        fn(telemetry)
        cold = telemetry.match_calls[-1]
        if seq_single:
            fn(telemetry)
            steady = telemetry.match_calls[-1]
            t = steady.wall_seconds
        else:
            t, _ = timed(fn, reps=r, warmup=0)
            fn(telemetry)
            steady = telemetry.match_calls[-1]
        stage_seconds = {
            s: cold.stage_seconds.get(s, 0.0) + steady.stage_seconds.get(s, 0.0)
            for s in obs.STAGES
        }
        timings[name] = {
            "seconds_per_call": t,
            "edges_per_sec": m / t if t > 0 else float("inf"),
            "reps": r,
            "backend": steady.backend,
            "interpret": steady.interpret,
            # stage split summed over the two instrumented calls (cold
            # contributes compile, steady contributes execute; host
            # stages appear in both) — disjoint subintervals, so the
            # stage sum never exceeds telemetry_wall_seconds
            "stage_seconds": stage_seconds,
            "telemetry_wall_seconds": cold.wall_seconds + steady.wall_seconds,
            "counters": {k: steady.counters[k] for k in sorted(steady.counters)},
        }
    speedup = (
        timings["pallas_waves"]["edges_per_sec"]
        / timings["pallas_edges"]["edges_per_sec"]
    )
    mega_vs_xla = (
        timings["pallas_mega"]["edges_per_sec"]
        / timings["waves_xla"]["edges_per_sec"]
    )
    return {
        "scale": scale,
        "n": cfg.n,
        "m": m,
        "L": L,
        "eps": eps,
        "num_waves": schedule.num_waves,
        "num_segments": schedule.num_segments,
        "seg_width": schedule.width,
        "max_wave_size": schedule.max_wave_size,
        "wave_fill": round(schedule.fill, 4),
        "edges_per_wave": round(m / max(schedule.num_waves, 1), 1),
        "schedule_seconds": schedule.schedule_seconds,
        "pack_seconds": schedule.pack_seconds,
        "validation": validation,
        "expected_counters": _expected_counters(schedule, cfg, L),
        "recovery": _bench_recovery(stream, cfg, schedule, reps),
        "engines": timings,
        "speedup_pallas_waves_vs_edges": round(speedup, 2),
        "speedup_mega_vs_xla": round(mega_vs_xla, 2),
    }


def run(scales=DEFAULT_SCALES, edge_factor=EDGE_FACTOR, L=L, eps=EPS, reps=3,
        emit_json=True, path: pathlib.Path | None = None):
    """Benchmark entry (rows for benchmarks.run + JSON side artifact)."""
    rows, _report = run_report(
        scales=scales, edge_factor=edge_factor, L=L, eps=eps, reps=reps,
        emit_json=emit_json, path=path,
    )
    return rows


def run_report(scales=DEFAULT_SCALES, edge_factor=EDGE_FACTOR, L=L, eps=EPS,
               reps=3, emit_json=True, path: pathlib.Path | None = None,
               telemetry=None):
    """Like :func:`run` but also returns the JSON report (for --check).

    ``telemetry`` (default: a fresh :class:`repro.obs.Telemetry`) is the
    session the instrumented cold/steady calls record into; pass your
    own to keep the trace (``--trace`` in :func:`main` does).
    """
    if telemetry is None:
        telemetry = obs.Telemetry()
    graphs = [_bench_graph(s, edge_factor, L, eps, reps, telemetry) for s in scales]
    min_speedup = min(g["speedup_pallas_waves_vs_edges"] for g in graphs)
    min_fill = min(g["wave_fill"] for g in graphs)
    min_mega = min(g["speedup_mega_vs_xla"] for g in graphs)
    max_overhead = max(g["recovery"]["snapshot_overhead_pct"] for g in graphs)
    all_bit_exact = all(g["recovery"]["resumed_bit_exact"] for g in graphs)
    clean_retries = sum(g["recovery"]["clean_retries"] for g in graphs)
    report = {
        "benchmark": "bench_throughput",
        "unit": "edges_per_sec",
        "config": {
            "scales": list(scales),
            "edge_factor": edge_factor,
            "L": L,
            "eps": eps,
            "reps": reps,
        },
        "graphs": graphs,
        "acceptance": {
            "target_speedup_pallas_waves_vs_edges": TARGET_SPEEDUP,
            "measured_min_speedup": min_speedup,
            "target_wave_fill": TARGET_FILL,
            "measured_min_wave_fill": min_fill,
            "target_mega_vs_xla": TARGET_MEGA_VS_XLA,
            "measured_min_mega_vs_xla": min_mega,
            "target_snapshot_overhead_pct": TARGET_SNAPSHOT_OVERHEAD_PCT,
            "measured_max_snapshot_overhead_pct": max_overhead,
            "resumed_bit_exact": all_bit_exact,
            "clean_retries": clean_retries,
            "pass": bool(
                min_speedup >= TARGET_SPEEDUP
                and min_fill >= TARGET_FILL
                and min_mega >= TARGET_MEGA_VS_XLA
                and max_overhead <= TARGET_SNAPSHOT_OVERHEAD_PCT
                and all_bit_exact
                and clean_retries == 0
            ),
        },
    }
    if emit_json:
        out = path or BENCH_PATH
        out.write_text(json.dumps(report, indent=2) + "\n")

    rows = []
    for g in graphs:
        tag = f"throughput_s{g['scale']}"
        for name, t in g["engines"].items():
            rows.append(
                (
                    f"{tag}_{name}",
                    t["seconds_per_call"] * 1e6,
                    f"{t['edges_per_sec']:.3e} edges/s",
                )
            )
        rows.append(
            (
                f"{tag}_waves",
                (g["schedule_seconds"] + g["pack_seconds"]) * 1e6,
                f"{g['num_waves']} waves {g['num_segments']} segs "
                f"fill={g['wave_fill']:.2f} "
                f"speedup={g['speedup_pallas_waves_vs_edges']:.1f}x "
                f"mega_vs_xla={g['speedup_mega_vs_xla']:.2f}x",
            )
        )
    return rows, report


def check_report(report: dict) -> tuple[bool, list[str]]:
    """The --check gate as a pure function: report dict in, verdict out.

    Returns ``(ok, messages)`` where every message names one gate with
    its measured and target values — PASS lines when satisfied, FAIL
    lines when violated. A structurally broken report (missing keys,
    no graphs) fails loudly instead of passing vacuously, so a refactor
    that stops emitting a gate input can never silently disable it.
    Gates, each enforced on EVERY benched graph:

    * ``pallas_waves`` >= ``TARGET_SPEEDUP`` x ``pallas_edges``;
    * wave fill >= ``TARGET_FILL``;
    * ``pallas_mega`` >= ``TARGET_MEGA_VS_XLA`` x ``waves_xla`` (the
      raised ISSUE-6 gate: the megakernel must beat the XLA oracle);
    * every engine row carries a complete, internally consistent
      telemetry block (all five ``stage_seconds`` keys, non-negative,
      summing within ``telemetry_wall_seconds``; a non-empty
      ``counters`` dict) — a refactor that drops the instrumentation
      fails here instead of silently un-observing the bench;
    * the wave/mega counters reproduce the plan accounting embedded in
      ``expected_counters`` **bit-exactly** (gather bytes, bit-block
      bytes, modeled HBM traffic);
    * the clean-path guard: every graph embeds a ``validation`` block
      with zero dropped edges / zero problems, and every Pallas engine
      row carries ``fallback.count == 0`` — the bench numbers must come
      from the engine they are labeled with, never from a silent
      fallback degradation, and a report without the guard record
      fails rather than passing vacuously;
    * the recovery gate (gate 7, ISSUE 9): every graph embeds a
      ``recovery`` block from the resumable path and on it the producer
      stall of per-epoch async snapshotting (time blocked in ``save``)
      is at most ``TARGET_SNAPSHOT_OVERHEAD_PCT`` of the identical
      chunked run without snapshots, the killed-and-resumed result is
      bit-exact against a one-shot run, and the guarded clean run
      logged zero ``guard.retry`` events — a report without the block
      fails rather than passing vacuously.
    """
    msgs: list[str] = []
    graphs = report.get("graphs")
    if not graphs:
        return False, ["FAIL report has no graphs (nothing was benched)"]
    ok = True
    gates = (
        ("speedup_pallas_waves_vs_edges", TARGET_SPEEDUP,
         "pallas_waves vs pallas_edges speedup"),
        ("wave_fill", TARGET_FILL, "wave fill"),
        ("speedup_mega_vs_xla", TARGET_MEGA_VS_XLA,
         "pallas_mega vs waves_xla speedup"),
    )
    for key, target, label in gates:
        missing = [g.get("scale", "?") for g in graphs if key not in g]
        if missing:
            ok = False
            msgs.append(f"FAIL {label}: key {key!r} missing at scales {missing}")
            continue
        worst = min(graphs, key=lambda g: g[key])
        verdict = worst[key] >= target
        ok = ok and verdict
        msgs.append(
            f"{'PASS' if verdict else 'FAIL'} {label}: min {worst[key]:.3g} "
            f"at scale {worst.get('scale', '?')} (target >= {target})"
        )

    # telemetry structure + internal consistency, every engine row
    problems: list[str] = []
    for g in graphs:
        scale = g.get("scale", "?")
        for name, row in g.get("engines", {}).items():
            where = f"scale {scale} engine {name}"
            stages = row.get("stage_seconds")
            if stages is None:
                problems.append(f"{where}: no stage_seconds")
                continue
            wall = row.get("telemetry_wall_seconds")
            if wall is None:
                problems.append(f"{where}: no telemetry_wall_seconds")
                continue
            problems.extend(
                f"{where}: {p}"
                for p in obs.consistency_problems(stages, wall)
            )
            if not row.get("counters"):
                problems.append(f"{where}: no counters")
    verdict = not problems
    ok = ok and verdict
    msgs.append(
        f"{'PASS' if verdict else 'FAIL'} telemetry stage_seconds/counters "
        f"on every engine row"
        + ("" if verdict else ": " + "; ".join(problems))
    )

    # plan-counter accounting: the emitted wave/mega counters must equal
    # the independently recomputed plan accounting bit-exactly
    mismatches: list[str] = []
    for g in graphs:
        scale = g.get("scale", "?")
        expected = g.get("expected_counters")
        if not expected:
            mismatches.append(f"scale {scale}: no expected_counters in report")
            continue
        for name, want in expected.items():
            got = g.get("engines", {}).get(name, {}).get("counters", {})
            for key, val in want.items():
                if key not in got:
                    mismatches.append(
                        f"scale {scale} engine {name}: counter {key!r} missing"
                    )
                elif got[key] != val:
                    mismatches.append(
                        f"scale {scale} engine {name}: {key} = {got[key]} "
                        f"!= expected {val}"
                    )
    verdict = not mismatches
    ok = ok and verdict
    msgs.append(
        f"{'PASS' if verdict else 'FAIL'} plan-counter accounting "
        f"(gather/bit-block/traffic bytes bit-exact)"
        + ("" if verdict else ": " + "; ".join(mismatches))
    )

    # clean-path guard: the bench input validated clean and no Pallas
    # engine silently degraded down the fallback cascade
    guard_problems: list[str] = []
    for g in graphs:
        scale = g.get("scale", "?")
        v = g.get("validation")
        if not v:
            guard_problems.append(f"scale {scale}: no validation block")
        else:
            for key in ("guard.dropped_edges", "guard.num_problems"):
                if v.get(key) != 0:
                    guard_problems.append(
                        f"scale {scale}: {key} = {v.get(key, 'missing')} "
                        f"on the clean bench path"
                    )
        for name, row in g.get("engines", {}).items():
            if not name.startswith("pallas_"):
                continue
            fb = row.get("counters", {}).get("fallback.count")
            if fb is None:
                guard_problems.append(
                    f"scale {scale} engine {name}: no fallback.count counter"
                )
            elif fb != 0:
                guard_problems.append(
                    f"scale {scale} engine {name}: fallback.count = {fb} "
                    f"(engine silently degraded)"
                )
    verdict = not guard_problems
    ok = ok and verdict
    msgs.append(
        f"{'PASS' if verdict else 'FAIL'} clean-path guard "
        f"(validation clean, fallback.count == 0 on every Pallas row)"
        + ("" if verdict else ": " + "; ".join(guard_problems))
    )

    # gate 7: the resumable path — per-epoch snapshotting within budget,
    # the killed-and-resumed result bit-exact, no retries on a clean run
    recovery_problems: list[str] = []
    for g in graphs:
        scale = g.get("scale", "?")
        rec = g.get("recovery")
        if not rec:
            recovery_problems.append(f"scale {scale}: no recovery block")
            continue
        pct = rec.get("snapshot_overhead_pct")
        if pct is None:
            recovery_problems.append(
                f"scale {scale}: no snapshot_overhead_pct"
            )
        elif pct > TARGET_SNAPSHOT_OVERHEAD_PCT:
            recovery_problems.append(
                f"scale {scale}: snapshot overhead {pct:.2f}% "
                f"(target <= {TARGET_SNAPSHOT_OVERHEAD_PCT}%)"
            )
        if rec.get("resumed_bit_exact") is not True:
            recovery_problems.append(
                f"scale {scale}: resumed result not bit-exact vs one-shot"
            )
        if rec.get("clean_retries") != 0:
            recovery_problems.append(
                f"scale {scale}: clean_retries = "
                f"{rec.get('clean_retries', 'missing')} (guard fired on a "
                f"clean path)"
            )
    verdict = not recovery_problems
    ok = ok and verdict
    msgs.append(
        f"{'PASS' if verdict else 'FAIL'} recovery gate (snapshot overhead "
        f"<= {TARGET_SNAPSHOT_OVERHEAD_PCT}%, resumed bit-exact, zero "
        f"clean-path retries)"
        + ("" if verdict else ": " + "; ".join(recovery_problems))
    )
    return ok, msgs


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scales", type=int, nargs="+", default=list(DEFAULT_SCALES))
    ap.add_argument("--edge-factor", type=int, default=EDGE_FACTOR)
    ap.add_argument("--L", type=int, default=L)
    ap.add_argument("--eps", type=float, default=EPS)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--no-json", action="store_true")
    ap.add_argument(
        "--check",
        action="store_true",
        help="exit non-zero unless on every benched graph wave_fill >= "
        "%.2f, wave-vs-edge speedup >= %.1f, mega >= %.1fx waves_xla, "
        "every engine row carries consistent telemetry, the input "
        "validated clean, no Pallas engine fell back, and the recovery "
        "block shows snapshot overhead <= %.1f%%, a bit-exact resume, "
        "and zero clean-path retries"
        % (TARGET_FILL, TARGET_SPEEDUP, TARGET_MEGA_VS_XLA,
           TARGET_SNAPSHOT_OVERHEAD_PCT),
    )
    ap.add_argument(
        "--trace",
        metavar="OUT_JSON",
        help="write the Chrome trace-event JSON of the instrumented "
        "bench calls here (open in ui.perfetto.dev)",
    )
    args = ap.parse_args()
    telemetry = obs.Telemetry()
    rows, report = run_report(
        scales=tuple(args.scales),
        edge_factor=args.edge_factor,
        L=args.L,
        eps=args.eps,
        reps=args.reps,
        emit_json=not args.no_json,
        telemetry=telemetry,
    )
    print("name,us_per_call,derived")
    for row in rows:
        print(f"{row[0]},{row[1]:.1f},{row[2]}")
    if not args.no_json:
        print(f"# wrote {BENCH_PATH}")
    if args.trace:
        telemetry.write_chrome_trace(args.trace)
        print(f"# wrote {args.trace}")
    if args.check:
        ok, msgs = check_report(report)
        for msg in msgs:
            print(f"# gate: {msg}")
        if not ok:
            sys.exit("bench gate FAILED (see gate lines above)")


if __name__ == "__main__":
    main()
