"""Roofline summary rows from the dry-run JSON (§5.11 optimality analogue
plus the 40-cell table feed for EXPERIMENTS.md)."""
import json
import os

from repro.launch.roofline import LINK_BW, PEAK_FLOPS
from repro.kernels.substream_match.ops import vmem_plan


def matching_kernel_roofline(L=64, eps=0.1):
    """§5.11: the FPGA achieves 175M e/s vs a 200M e/s (1 edge/cycle) bound.

    TPU analogue: the kernel retires 1 edge per fori_loop iteration; the
    per-edge work is 2 row loads + 2 row stores of L_pad lanes (int8) + an
    L_pad-wide compare/AND — VPU-bound. At ~940 MHz with ~4 vector ops/edge
    + loop overhead (~8 cycles/edge conservatively), the bound is
    ~115M edges/s/core; the stream DMA needs 8 B/edge (0.9 GB/s) << HBM bw,
    matching the paper's conclusion that the pipeline, not DRAM, limits.
    """
    plan = vmem_plan(2**15, L, packed=True)
    cycles_per_edge = 8
    clock = 940e6
    edges_per_s = clock / cycles_per_edge
    return {
        "edges_per_s_bound": edges_per_s,
        "vmem_bytes": plan.nbytes,
        # stream + amortized packed bit rows (width bytes per vertex touch)
        "dma_bytes_per_edge": 8 + plan.width / 8,
    }


def run(path="dryrun_results.json"):
    rows = []
    mk = matching_kernel_roofline()
    rows.append(
        (
            "roofline/substream_match_kernel",
            0.0,
            f"bound={mk['edges_per_s_bound']/1e6:.0f}Me/s;vmem={mk['vmem_bytes']/2**20:.1f}MiB",
        )
    )
    if not os.path.exists(path):
        rows.append(("roofline/dryrun", 0.0, "dryrun_results.json missing"))
        return rows
    data = json.load(open(path))
    ok = sum(1 for v in data.values() if "error" not in v)
    rows.append(("roofline/cells_ok", 0.0, f"{ok}/{len(data)}"))
    best = {}
    for v in data.values():
        if "error" in v or v["mesh"] != "16x16":
            continue
        rf = v["roofline"]
        rows.append(
            (
                f"roofline/{v['arch']}/{v['shape']}",
                rf["step_time_lower_bound_s"] * 1e6,
                f"dom={rf['dominant']};frac={rf.get('roofline_fraction', 0):.4f}",
            )
        )
    return rows
