"""Roofline summary rows from the dry-run JSON (§5.11 optimality analogue
plus the 40-cell table feed for EXPERIMENTS.md).

Since the telemetry counters landed in ``BENCH_substream.json`` (every
engine row carries ``traffic.hbm_bytes``, the modeled stream + bit-row
traffic of its plan), this report also derives the *achieved* fraction
of the substream kernel bound per engine and scale — the measured
edges/sec over :func:`repro.launch.roofline.substream_bound` at that
row's bytes-per-edge. One model (``launch/roofline``), two consumers
(per-call ``MatchTelemetry.roofline()`` and this table).
"""
import json
import os
import pathlib

from repro.launch.roofline import (
    LINK_BW,
    PEAK_FLOPS,
    SUBSTREAM_CLOCK,
    SUBSTREAM_CYCLES_PER_EDGE,
    substream_achieved,
)
from repro.kernels.substream_match.ops import vmem_plan

BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_substream.json"


def matching_kernel_roofline(L=64, eps=0.1):
    """§5.11: the FPGA achieves 175M e/s vs a 200M e/s (1 edge/cycle) bound.

    TPU analogue: the kernel retires 1 edge per fori_loop iteration; the
    per-edge work is 2 row loads + 2 row stores of L_pad lanes (int8) + an
    L_pad-wide compare/AND — VPU-bound. At ~940 MHz with ~4 vector ops/edge
    + loop overhead (~8 cycles/edge conservatively), the bound is
    ~115M edges/s/core; the stream DMA needs 8 B/edge (0.9 GB/s) << HBM bw,
    matching the paper's conclusion that the pipeline, not DRAM, limits.
    The clock/cycle constants live in :mod:`repro.launch.roofline`
    (``SUBSTREAM_CLOCK`` / ``SUBSTREAM_CYCLES_PER_EDGE``) — shared with
    the per-call telemetry roofline.
    """
    plan = vmem_plan(2**15, L, packed=True)
    edges_per_s = SUBSTREAM_CLOCK / SUBSTREAM_CYCLES_PER_EDGE
    return {
        "edges_per_s_bound": edges_per_s,
        "vmem_bytes": plan.nbytes,
        # stream + amortized packed bit rows (width bytes per vertex touch)
        "dma_bytes_per_edge": 8 + plan.width / 8,
    }


def substream_achieved_rows(bench_path=BENCH_PATH):
    """Achieved-vs-bound fraction per engine/scale from the bench record.

    Reads the telemetry counters of ``BENCH_substream.json``: engines
    that model their HBM traffic (``traffic.hbm_bytes`` — the Pallas
    pipelines) get one row each with the achieved fraction of the
    pipeline/memory bound at their measured bytes-per-edge.
    """
    rows = []
    if not os.path.exists(bench_path):
        return [("roofline/substream_achieved", 0.0, "BENCH_substream.json missing")]
    report = json.load(open(bench_path))
    for g in report.get("graphs", []):
        m = g.get("m", 0)
        for name, row in g.get("engines", {}).items():
            nbytes = row.get("counters", {}).get("traffic.hbm_bytes")
            if nbytes is None or not m:
                continue  # engine has no traffic model (scan / XLA paths)
            terms = substream_achieved(row["edges_per_sec"], nbytes / m)
            rows.append(
                (
                    f"roofline/substream/{name}_s{g.get('scale', '?')}",
                    row["seconds_per_call"] * 1e6,
                    f"frac={terms['achieved_fraction']:.2e};"
                    f"dom={terms['dominant']};"
                    f"bpe={terms['bytes_per_edge']:.1f}",
                )
            )
    if len(rows) == 0:
        rows.append(
            (
                "roofline/substream_achieved",
                0.0,
                "no traffic.hbm_bytes counters in BENCH_substream.json",
            )
        )
    return rows


def run(path="dryrun_results.json"):
    rows = []
    mk = matching_kernel_roofline()
    rows.append(
        (
            "roofline/substream_match_kernel",
            0.0,
            f"bound={mk['edges_per_s_bound']/1e6:.0f}Me/s;vmem={mk['vmem_bytes']/2**20:.1f}MiB",
        )
    )
    rows.extend(substream_achieved_rows())
    if not os.path.exists(path):
        rows.append(("roofline/dryrun", 0.0, "dryrun_results.json missing"))
        return rows
    data = json.load(open(path))
    ok = sum(1 for v in data.values() if "error" not in v)
    rows.append(("roofline/cells_ok", 0.0, f"{ok}/{len(data)}"))
    best = {}
    for v in data.values():
        if "error" in v or v["mesh"] != "16x16":
            continue
        rf = v["roofline"]
        rows.append(
            (
                f"roofline/{v['arch']}/{v['shape']}",
                rf["step_time_lower_bound_s"] * 1e6,
                f"dom={rf['dominant']};frac={rf.get('roofline_fraction', 0):.4f}",
            )
        )
    return rows
