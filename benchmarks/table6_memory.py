"""Table 6 analogue: resource usage. The FPGA's BRAM/ALM budget maps to the
kernel's VMEM bit-block plan; we report the planned bytes for the paper's
configurations (SC-OPT K=32/L=512 etc.) against the 16 MiB v5e VMEM the
way Table 6 reports 55 Mbit Arria-10 BRAM — for BOTH matching-bit
layouts, plus the resulting single-core vertex capacity: the packed
uint8 bit-plane layout (the §4.3 BRAM-word analogue) fits 8-16x the
vertices of the legacy one-int8-per-bit layout."""
from repro.kernels.substream_match.ops import (
    VMEM_BIT_BUDGET,
    max_vertices,
    vmem_plan,
)


def run():
    rows = []
    cases = [
        ("sc_simple_logB12_L8", 2**12 // 8, 8),
        ("sc_simple_logB18_L6", 2**18 // 6, 6),
        ("sc_opt_K32_L512", 2**15, 512),
        ("sc_opt_K256_L128", 2**17, 128),
    ]
    for name, n, L in cases:
        packed = vmem_plan(n, L, packed=True)
        unpacked = vmem_plan(n, L, packed=False)
        rows.append(
            (
                f"table6/{name}",
                0.0,
                f"vmem_packed={packed.nbytes/2**20:.2f}MiB"
                f"({100*packed.nbytes/VMEM_BIT_BUDGET:.0f}%of-budget);"
                f"unpacked={unpacked.nbytes/2**20:.1f}MiB"
                f"({100*unpacked.nbytes/VMEM_BIT_BUDGET:.0f}%);"
                f"block_e={packed.block_e}",
            )
        )
    for L in (8, 64, 512):
        cap_p = max_vertices(L, packed=True)
        cap_u = max_vertices(L, packed=False)
        rows.append(
            (
                f"table6/capacity_L{L}",
                0.0,
                f"max_vertices packed={cap_p} unpacked={cap_u} "
                f"gain={cap_p/cap_u:.1f}x",
            )
        )
    return rows
