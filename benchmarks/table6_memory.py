"""Table 6 analogue: resource usage. The FPGA's BRAM/ALM budget maps to the
kernel's VMEM bit-block plan; we report the planned bytes for the paper's
configurations (SC-OPT K=32/L=512 etc.) against the 16 MiB v5e VMEM the
way Table 6 reports 55 Mbit Arria-10 BRAM."""
from repro.kernels.substream_match.ops import VMEM_BIT_BUDGET, vmem_plan


def run():
    rows = []
    cases = [
        ("sc_simple_logB12_L8", 2**12 // 8, 8),
        ("sc_simple_logB18_L6", 2**18 // 6, 6),
        ("sc_opt_K32_L512", 2**15, 512),
        ("sc_opt_K256_L128", 2**17, 128),
    ]
    for name, n, L in cases:
        n_pad, L_pad, nbytes = vmem_plan(n, L)
        rows.append(
            (
                f"table6/{name}",
                0.0,
                f"vmem={nbytes/2**20:.1f}MiB({100*nbytes/VMEM_BIT_BUDGET:.0f}%of-budget)",
            )
        )
    return rows
