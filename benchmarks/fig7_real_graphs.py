"""Fig. 7: real-world graphs (offline standins with matched degree, see
DESIGN.md §7). Paper: SC-OPT fastest on every graph (45-140M e/s)."""
from benchmarks.common import timed
from repro.core import EdgeStream, SubstreamConfig, gseq, mwm_blocked, mwm_scan
from repro.graph.generators import kronecker_graph, uniform_weights

# (name, scale, edge_factor) — degree-matched standins
STANDINS = [("arxiv-like", 11, 12), ("stanford-like", 12, 8), ("gowalla-like", 13, 5)]


def run(L=16, eps=0.1):
    rows = []
    for name, scale, ef in STANDINS:
        src, dst = kronecker_graph(scale, ef, seed=3)
        w = uniform_weights(len(src), L, eps, seed=3)
        cfg = SubstreamConfig(n=1 << scale, L=L, eps=eps)
        stream = EdgeStream.from_numpy(src, dst, w)
        m = len(src)
        dt, _ = timed(lambda: mwm_blocked(stream, cfg, K=32))
        rows.append((f"fig7/sc_blocked/{name}", dt * 1e6, f"{m/dt/1e6:.2f}Me/s"))
        dt, _ = timed(lambda: gseq(stream, cfg.n, eps), reps=2)
        rows.append((f"fig7/gseq/{name}", dt * 1e6, f"{m/dt/1e6:.2f}Me/s"))
    return rows
