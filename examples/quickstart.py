"""Quickstart: substream-centric (4+eps)-approx maximum weighted matching.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (
    EdgeStream,
    SubstreamConfig,
    exact_mwm_weight,
    mwm_pipeline,
)
from repro.graph.generators import kronecker_graph, uniform_weights
from repro.kernels.substream_match.ops import max_vertices, vmem_plan


def main():
    L, eps = 16, 0.1
    src, dst = kronecker_graph(scale=8, edge_factor=8, seed=0)
    w = uniform_weights(len(src), L, eps, seed=0)
    stream = EdgeStream.from_numpy(src, dst, w)
    cfg = SubstreamConfig(n=256, L=L, eps=eps)

    for variant in ("scan", "blocked", "rounds", "pallas"):
        kw = dict(block_e=256) if variant == "pallas" else {}
        idx, weight = mwm_pipeline(stream, cfg, part1=variant, **kw)
        print(f"{variant:8s}: |T|={len(idx):4d}  w(T)={weight:9.2f}")

    exact = exact_mwm_weight(stream)
    idx, weight = mwm_pipeline(stream, cfg)
    print(f"exact MWM weight {exact:.2f}; ratio {exact/weight:.3f} "
          f"(guarantee <= {4 + eps})")

    plan = vmem_plan(cfg.n, cfg.L)
    print(f"packed bit block: {plan.nbytes} B ({plan.width} B/vertex); "
          f"single-core capacity at L={L}: {max_vertices(L):,} vertices "
          f"({max_vertices(L) // max_vertices(L, packed=False)}x unpacked)")


if __name__ == "__main__":
    main()
