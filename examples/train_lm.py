"""Train a transformer LM end-to-end with the full production loop:
AdamW + WSD schedule, gradient clipping, checkpoint/restart, straggler
monitoring. Presets: tiny (CPU-friendly) / 100m.

    PYTHONPATH=src python examples/train_lm.py --steps 60
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 200
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import TokenPipeline
from repro.distributed import StragglerMonitor
from repro.models import transformer as tfm
from repro.models.param import abstract_params, count_params, init_params
from repro.optim import AdamWConfig, adamw_init, adamw_update, wsd_schedule

PRESETS = {
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv=2, d_head=32,
                 d_ff=256, vocab=2048, seq=128, batch=8),
    "100m": dict(n_layers=8, d_model=768, n_heads=12, n_kv=4, d_head=64,
                 d_ff=2048, vocab=32768, seq=512, batch=8),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = tfm.TransformerConfig(
        name=f"lm-{args.preset}", n_layers=p["n_layers"], d_model=p["d_model"],
        n_heads=p["n_heads"], n_kv=p["n_kv"], d_head=p["d_head"], d_ff=p["d_ff"],
        vocab=p["vocab"], param_dtype=jnp.float32, attn_chunk=64, loss_chunk=64,
    )
    print(f"model: {count_params(tfm.param_specs(cfg))/1e6:.1f}M params")
    opt_cfg = AdamWConfig(lr=3e-3, weight_decay=0.01)
    pipe = TokenPipeline(cfg.vocab, p["batch"], p["seq"], seed=0)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    mon = StragglerMonitor()

    params = init_params(tfm.param_specs(cfg), jax.random.key(0))
    opt = adamw_init(params, opt_cfg)
    start = 0
    tmpl = {"params": abstract_params(tfm.param_specs(cfg)), "opt": opt}
    step0, restored = mgr.restore(tmpl)
    if restored is not None:
        params, opt = restored["params"], restored["opt"]
        start = step0
        print(f"restored checkpoint at step {start} (restart-from-failure path)")

    @jax.jit
    def train_step(params, opt, tokens, lr):
        loss, grads = jax.value_and_grad(lambda p: tfm.loss_fn(p, tokens, cfg))(params)
        params, opt, gnorm = adamw_update(params, grads, opt, lr, opt_cfg)
        return params, opt, loss, gnorm

    for step in range(start, args.steps):
        tokens = jnp.asarray(pipe.batch_at(step))
        lr = wsd_schedule(step, opt_cfg.lr, warmup=10, stable=args.steps // 2,
                          decay=args.steps // 2)
        mon.start()
        params, opt, loss, gnorm = train_step(params, opt, tokens, lr)
        loss.block_until_ready()
        ev = mon.stop()
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:4d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} lr {float(lr):.2e}"
                  + (f" [straggler x{ev.ratio:.1f}]" if ev else ""))
        if step and step % args.ckpt_every == 0:
            mgr.save(step, {"params": params, "opt": opt})
    mgr.wait()
    print("done; final checkpoint steps:", mgr.all_steps())


if __name__ == "__main__":
    main()
