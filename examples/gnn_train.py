"""Train GIN on a sampled-minibatch workload using the real neighbor
sampler + matching-based graph coarsening from the paper's substrate.

    PYTHONPATH=src python examples/gnn_train.py --steps 20
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import make_gnn_batch
from repro.graph import CSRGraph, NeighborSampler, coarsen_by_matching
from repro.graph.generators import kronecker_graph, uniform_weights
from repro.models import gin
from repro.models.gnn_common import GraphBatch
from repro.models.param import init_params
from repro.optim import AdamWConfig, adamw_init, adamw_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    args = ap.parse_args()

    # graph substrate: kronecker graph + matching-based coarsening stats
    src, dst = kronecker_graph(10, edge_factor=8, seed=0)
    w = uniform_weights(len(src), 16, 0.1, seed=0)
    n = 1024
    mapping, cs, cd, cw = coarsen_by_matching(src, dst, w, n=n, L=16)
    print(f"coarsen-by-matching: {n} -> {mapping.max()+1} vertices "
          f"({len(src)} -> {len(cs)} edges) — paper technique as GNN preproc")

    csr = CSRGraph.from_edges(src, dst, w, n=n, symmetrize=True)
    sampler = NeighborSampler(csr, fanouts=[10, 5], seed=0)

    cfg = gin.GINConfig(n_layers=3, d_hidden=32, d_in=16, n_classes=8)
    params = init_params(gin.param_specs(cfg), jax.random.key(0))
    opt_cfg = AdamWConfig(lr=2e-3)
    opt = adamw_init(params, opt_cfg)
    rng = np.random.default_rng(0)
    feats = rng.normal(size=(n, 16)).astype(np.float32)
    labels = rng.integers(0, 8, n)

    @jax.jit
    def step_fn(params, opt, batch):
        loss, grads = jax.value_and_grad(lambda p: gin.loss_fn(p, batch, cfg))(params)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg.lr, opt_cfg)
        return params, opt, loss

    N_PAD, E_PAD = 2048, 8192  # static shapes across steps (jit cache)
    for step in range(args.steps):
        seeds = rng.integers(0, n, 64)
        blocks = sampler.sample(seeds)
        # merge hops into one padded subgraph (same flat form as prod)
        nodes = blocks[-1].nodes[blocks[-1].node_mask]
        remap = {g: i for i, g in enumerate(nodes)}
        # flatten hop-0 sampled edges into the merged local id space
        b0 = blocks[0]
        sel = np.nonzero(b0.edge_mask)[0]
        src_g = b0.nodes[b0.src_index[sel]]
        dst_g = seeds[b0.dst_index[sel]]
        keep = np.array([g in remap for g in src_g])
        src_l = np.array([remap[g] for g in src_g[keep]], np.int32)
        dst_l = np.array([remap.get(g, 0) for g in dst_g[keep]], np.int32)
        ne, nn = len(src_l), len(nodes)
        batch = GraphBatch(
            node_feats=jnp.asarray(np.pad(feats[nodes], ((0, N_PAD - nn), (0, 0)))),
            src=jnp.asarray(np.pad(src_l, (0, E_PAD - ne))),
            dst=jnp.asarray(np.pad(dst_l, (0, E_PAD - ne))),
            edge_mask=jnp.asarray(np.arange(E_PAD) < ne),
            node_mask=jnp.asarray(np.arange(N_PAD) < nn),
            labels=jnp.asarray(np.pad(labels[nodes], (0, N_PAD - nn)), jnp.int32),
            label_mask=jnp.asarray(np.arange(N_PAD) < nn),
        )
        params, opt, loss = step_fn(params, opt, batch)
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:3d} sampled {nn} nodes / {ne} edges; "
                  f"loss {float(loss):.4f}")


if __name__ == "__main__":
    main()
