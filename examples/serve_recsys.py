"""Serve BERT4Rec: batched request scoring + retrieval against a candidate
set with the two-stage sharded top-k.

    PYTHONPATH=src python examples/serve_recsys.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.data.pipeline import RecsysPipeline
from repro.launch.steps import sharded_topk
from repro.models import bert4rec as b4r
from repro.models.param import init_params


def main():
    arch = get_arch("bert4rec")
    cfg = arch.smoke_config
    params = init_params(b4r.param_specs(cfg), jax.random.key(0))
    pipe = RecsysPipeline(cfg.item_vocab, 32, cfg.seq_len, cfg.n_mask,
                          cfg.n_negatives, cfg.n_context, seed=1)

    @jax.jit
    def serve(params, item_ids, context_ids):
        scores = b4r.serve_scores(params, item_ids, context_ids, cfg)
        return sharded_topk(scores, k=10, shards=4)

    batch = pipe.batch_at(0)
    vals, idxs = serve(params, batch["item_ids"], batch["context_ids"])
    t0 = time.perf_counter()
    for s in range(5):
        b = pipe.batch_at(s)
        vals, idxs = serve(params, b["item_ids"], b["context_ids"])
        vals.block_until_ready()
    dt = (time.perf_counter() - t0) / 5
    print(f"batched serving: {32/dt:.0f} req/s (batch 32, vocab {cfg.item_vocab})")
    print("top-5 items for request 0:", np.asarray(idxs[0][:5]),
          "scores:", np.round(np.asarray(vals[0][:5]), 3))

    cands = jnp.asarray(np.random.default_rng(2).integers(0, cfg.item_vocab, 256), jnp.int32)
    sc = b4r.score_candidates(params, batch["item_ids"][:1],
                              batch["context_ids"][:1], cands, cfg)
    print(f"retrieval scoring vs {len(cands)} candidates:", sc.shape)


if __name__ == "__main__":
    main()
