"""End-to-end driver for the paper's system: stream a Kronecker graph
through the custom CSR layout, run Part 1 on the (interpreted) Pallas
kernel in lexicographic epoch order, merge on the host, and report
approximation + throughput + the paper's DRAM-traffic model. Includes
checkpoint/restart of the stream position (fault-tolerance demo).

    PYTHONPATH=src python examples/matching_e2e.py --scale 10 --L 32
"""
import argparse
import time

import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import (
    EdgeStream,
    SubstreamConfig,
    exact_mwm_weight,
    matching_weight,
    merge_host,
    mwm_blocked,
)
from repro.distributed import StragglerMonitor
from repro.graph.csr import CSRGraph, CustomCSR
from repro.graph.generators import kronecker_graph, uniform_weights


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=int, default=10)
    ap.add_argument("--edge-factor", type=int, default=16)
    ap.add_argument("--L", type=int, default=32)
    ap.add_argument("--eps", type=float, default=0.1)
    ap.add_argument("--K", type=int, default=32)
    ap.add_argument("--ckpt-dir", default="/tmp/matching_ckpt")
    args = ap.parse_args()

    n = 1 << args.scale
    src, dst = kronecker_graph(args.scale, args.edge_factor, seed=0)
    w = uniform_weights(len(src), args.L, args.eps, seed=0)
    csr = CSRGraph.from_edges(src, dst, w, n=n)
    custom = CustomCSR.encode(csr)
    print(f"graph: n={n} m={csr.m}; custom CSR DRAM bytes={custom.dram_bytes}"
          f" ({custom.read_requests_per_edge()} req/edge — §5.11 model)")

    s2, d2, w2 = custom.decode().to_stream_arrays()
    stream = EdgeStream.from_numpy(s2, d2, w2)
    cfg = SubstreamConfig(n=n, L=args.L, eps=args.eps)

    mon = StragglerMonitor()
    mgr = CheckpointManager(args.ckpt_dir, async_save=False)
    t0 = time.perf_counter()
    mon.start()
    res = mwm_blocked(stream, cfg, K=args.K, backend="pallas", block_e=1024)
    ev = mon.stop()
    part1_s = time.perf_counter() - t0
    mgr.save(1, {"part1": {"assigned": res.assigned, "mb": res.mb}})
    print(f"Part 1 (pallas, K={args.K}): {part1_s:.2f}s "
          f"({csr.m/part1_s/1e6:.2f} Me/s interpret-mode)"
          + (f"; straggler flagged ratio={ev.ratio:.1f}" if ev else ""))

    t0 = time.perf_counter()
    idx = merge_host(stream, res, cfg)
    merge_s = time.perf_counter() - t0
    weight = matching_weight(stream, idx)
    print(f"Part 2 (host merge): {merge_s:.3f}s "
          f"({100*merge_s/(merge_s+part1_s):.1f}% of total — paper: <1%)")
    print(f"|T|={len(idx)} w(T)={weight:.1f}")
    if n <= 2048:
        exact = exact_mwm_weight(stream)
        print(f"exact={exact:.1f} ratio={exact/weight:.3f} <= {4+args.eps}")
    # restart demo: restore part1 output and re-merge
    step, state = mgr.restore({"part1": {"assigned": res.assigned, "mb": res.mb}})
    res2 = res.with_assigned(state["part1"]["assigned"])
    idx2 = merge_host(stream, res2, cfg)
    assert (idx2 == idx).all()
    print(f"checkpoint restart at step {step}: merge reproduced exactly")


if __name__ == "__main__":
    main()
